//! Alloc-accountability pass: the serve memory budget (`SessionPool`'s
//! reserve-then-true-up admission) and `imm_memory_limit` (the rr
//! store's exact byte accounting) only mean something if heap growth on
//! those paths is *accounted* — charged to the budget before it
//! happens, or documented as transient and bounded. This pass scans the
//! budget-admitted surfaces — `serve/pool.rs` and everything under
//! `rr/` — and flags heap-allocating calls that are neither inside an
//! accounted region nor annotated.
//!
//! Tokens flagged (`alloc-unaccounted`): `Vec::new(` /
//! `with_capacity(` / `.collect(` / `collect::<` / `Box::new(` /
//! `Arc::new(` / `vec![` / `.to_vec(` / `.clone()`. `Arc::clone` /
//! `Rc::clone` are exempt (refcount bumps, not allocations).
//!
//! Clearing a site:
//!
//! * a `// ACCOUNTED:` comment within [`ACCOUNTED_WINDOW`] lines above
//!   the site, stating which budget the bytes are charged to (or why
//!   they are transient and bounded); or
//! * an *accounted region*: a `// ACCOUNTED:` comment within the window
//!   above the enclosing fn's declaration, which clears every site in
//!   that fn — for functions whose whole job is charged allocation
//!   (e.g. the store append path, whose capacity was admitted via
//!   `bytes_after` before any allocation).
//!
//! Deleting an annotation re-opens every site it cleared; the
//! acceptance self-test checks exactly that against the real tree.

use crate::findings::Finding;
use crate::graph::CrateModel;
use crate::lexer::comment_in_window;
use crate::parser::SourceFile;

/// How many lines above a site (or a fn declaration) the `ACCOUNTED:`
/// comment may sit.
pub(crate) const ACCOUNTED_WINDOW: usize = 10;

/// The budget-admitted surfaces.
const SCOPE_FILES: [&str; 1] = ["serve/pool.rs"];
const SCOPE_DIRS: [&str; 1] = ["rr/"];

const ALLOC_TOKENS: [&str; 9] = [
    "Vec::new(",
    "with_capacity(",
    ".collect(",
    "collect::<",
    "Box::new(",
    "Arc::new(",
    "vec![",
    ".to_vec(",
    ".clone()",
];

fn in_scope(rel: &str) -> bool {
    SCOPE_FILES.contains(&rel) || SCOPE_DIRS.iter().any(|d| rel.starts_with(d))
}

fn alloc_token_at(code: &str) -> Option<&'static str> {
    for t in ALLOC_TOKENS {
        if code.contains(t) {
            // Refcount bumps are not allocations.
            if t == ".clone()" && (code.contains("Arc::clone") || code.contains("Rc::clone")) {
                continue;
            }
            return Some(t);
        }
    }
    None
}

pub(crate) fn run(model: &CrateModel) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in &model.files {
        if !in_scope(&file.rel) {
            continue;
        }
        scan_file(file, &mut out);
    }
    out
}

fn scan_file(file: &SourceFile, out: &mut Vec<Finding>) {
    for i in 0..file.lines.len() {
        if file.mask[i] {
            continue;
        }
        let Some(token) = alloc_token_at(&file.lines[i].code) else { continue };
        // Allocation outside any fn body (consts, statics) has no
        // runtime accounting story to check.
        let Some(f) = super::enclosing_fn(file, i) else { continue };
        let site_ok = comment_in_window(&file.lines, i, ACCOUNTED_WINDOW, &["ACCOUNTED"]);
        let region_ok = comment_in_window(&file.lines, f.line, ACCOUNTED_WINDOW, &["ACCOUNTED"]);
        if site_ok || region_ok {
            continue;
        }
        out.push(Finding::new(
            "alloc-accountability",
            "alloc-unaccounted",
            &file.rel,
            i + 1,
            &f.name,
            format!(
                "heap allocation (`{token}`) on a budget-admitted path without an \
                 `// ACCOUNTED:` annotation: charge it to the session/store budget \
                 before allocating, or document why it is transient and bounded"
            ),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(sources: &[(&str, &str)]) -> Vec<(String, usize, String)> {
        let model = CrateModel::from_sources(sources);
        run(&model).into_iter().map(|f| (f.file, f.line, f.symbol)).collect()
    }

    #[test]
    fn collect_on_the_budget_path_fires_and_site_annotation_clears() {
        let bad = "pub fn stats(&self) -> Vec<u32> {\n    self.xs.iter().map(|x| x + 1).collect()\n}\n";
        let got = findings(&[("serve/pool.rs", bad)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].1, 2);
        assert_eq!(got[0].2, "stats");

        let good = "pub fn stats(&self) -> Vec<u32> {\n    // ACCOUNTED: O(sessions) observability snapshot, not session-owned bytes.\n    self.xs.iter().map(|x| x + 1).collect()\n}\n";
        assert!(findings(&[("serve/pool.rs", good)]).is_empty());
    }

    #[test]
    fn fn_level_region_clears_every_site_inside() {
        let region = concat!(
            "// ACCOUNTED: append path; capacity was admitted via bytes_after\n",
            "// before any allocation below runs.\n",
            "pub fn append(&mut self, n: usize) {\n",
            "    let mut buf = Vec::with_capacity(n);\n",
            "    buf.push(1u8);\n",
            "    self.arena = buf.to_vec();\n",
            "}\n",
        );
        assert!(findings(&[("rr/mod.rs", region)]).is_empty());
    }

    #[test]
    fn arc_clone_is_exempt_but_deep_clone_is_not() {
        let refcount = "pub fn share(&self) -> Arc<S> {\n    Arc::clone(&self.s)\n}\n";
        assert!(findings(&[("serve/pool.rs", refcount)]).is_empty());

        let deep = "pub fn snapshot(&self) -> String {\n    self.name.clone()\n}\n";
        let got = findings(&[("serve/pool.rs", deep)]);
        assert_eq!(got.len(), 1, "{got:?}");
    }

    #[test]
    fn out_of_scope_files_and_test_code_are_exempt() {
        let alloc = "pub fn anywhere() -> Vec<u32> {\n    vec![1, 2, 3]\n}\n";
        assert!(findings(&[("serve/mod.rs", alloc)]).is_empty());
        assert!(findings(&[("algo/mod.rs", alloc)]).is_empty());

        let test_only = concat!(
            "pub fn quiet() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() {\n",
            "        let v: Vec<u32> = (0..4).collect();\n",
            "        drop(v);\n",
            "    }\n",
            "}\n",
        );
        assert!(findings(&[("rr/codec.rs", test_only)]).is_empty());
    }

    #[test]
    fn deleting_an_annotation_reopens_the_site() {
        let annotated = "pub fn grow(&mut self) {\n    // ACCOUNTED: charged to entries_bytes one line up.\n    self.entries = Vec::with_capacity(8);\n}\n";
        assert!(findings(&[("rr/mod.rs", annotated)]).is_empty());
        let stripped = annotated.replace("// ACCOUNTED: charged to entries_bytes one line up.", "");
        let got = findings(&[("rr/mod.rs", &stripped)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].2, "grow");
    }
}
