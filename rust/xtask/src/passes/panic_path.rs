//! Panic-path pass: a panic on a serving path kills a request thread
//! (or, outside the `dispatch` catch_unwind, the whole server), so the
//! multi-tenant story in `serve/` only holds if every function reachable
//! from the request loop or from `ImSession::query` is panic-free.
//!
//! Reachability is fn-level over [`CallGraph`]: every non-test function
//! in `serve/` is a root (the accept loop, the reader, and the dispatch
//! table are all private), plus `query` in `api/session.rs`. Resolution
//! over-approximates (methods widen to every definition), which for a
//! *no-panic* gate is the safe direction — scope grows, sites cannot
//! hide.
//!
//! Rules, on every non-test line of a reachable body:
//!
//! * `pp-unwrap` — `.unwrap()` / `.expect(` calls. Files that define a
//!   non-test `fn expect` of their own (the `util/json.rs` pull parser)
//!   are exempt from the `.expect(` half only.
//! * `pp-panic` — `panic!` / `unreachable!` / `todo!` / `unimplemented!`
//!   invocations (`assert!` family is deliberately allowed: those state
//!   invariants, and the serve loop maps them through catch_unwind).
//! * `pp-index` — unchecked `x[..]` indexing, restricted to `serve/`
//!   and `api/` files: that is the tenant boundary where an
//!   out-of-bounds panic crosses sessions; kernel-internal indexing is
//!   bounds-certified by the SAFETY/lint machinery instead.
//!
//! A site is accepted when a `// PANIC-OK:` comment within
//! [`PANIC_OK_WINDOW`] lines above states why it cannot fire (the
//! SAFETY/ORDERING/DETERMINISM convention extended).

use crate::findings::Finding;
use crate::graph::{CrateModel, Def};
use crate::lexer::{comment_in_window, has_word_followed_by, is_ident_byte};
use std::collections::BTreeSet;

/// How many lines above a site the `PANIC-OK:` comment may sit.
pub(crate) const PANIC_OK_WINDOW: usize = 10;

/// Operator-facing and checker-internal surfaces where a panic answers
/// to a human or is the failure-reporting mechanism itself, not a
/// served request: the CLI binaries, the bench/coordinator harness, and
/// the loom-personality model checker (test-only, panics by design).
const ALLOW_FILES: [&str; 5] =
    ["main.rs", "bench.rs", "util/args.rs", "util/proptest_lite.rs", "runtime/sync/model.rs"];
const ALLOW_DIRS: [&str; 1] = ["coordinator/"];

/// Files where `pp-index` applies (see the module docs).
const INDEX_DIRS: [&str; 2] = ["serve/", "api/"];

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

fn allowlisted(rel: &str) -> bool {
    ALLOW_FILES.contains(&rel) || ALLOW_DIRS.iter().any(|d| rel.starts_with(d))
}

/// Seed set: every non-test fn in `serve/`, plus `ImSession::query`.
fn seeds(model: &CrateModel, cg: &crate::graph::CallGraph<'_>) -> Vec<Def> {
    let mut out = Vec::new();
    for (fi, file) in model.files.iter().enumerate() {
        if file.rel.starts_with("serve/") {
            out.extend(cg.fns_in_file(fi, |_| true));
        }
        if file.rel == "api/session.rs" {
            out.extend(cg.fns_in_file(fi, |f| f.name == "query"));
        }
    }
    out
}

pub(crate) fn run(model: &CrateModel) -> Vec<Finding> {
    let cg = model.call_graph();
    let reachable = cg.reachable_fns(seeds(model, &cg));

    // Nested fns are spanned by their enclosing fn too; dedup by line.
    let mut lines_to_scan: BTreeSet<(usize, usize)> = BTreeSet::new();
    for def in &reachable {
        let Some(item) = cg.fn_item(*def) else { continue };
        let Some((lo, hi)) = item.body else { continue };
        let file = &model.files[def.file()];
        if allowlisted(&file.rel) {
            continue;
        }
        for i in lo..=hi.min(file.lines.len() - 1) {
            if !file.mask[i] {
                lines_to_scan.insert((def.file(), i));
            }
        }
    }

    let mut out = Vec::new();
    for (fi, i) in lines_to_scan {
        let file = &model.files[fi];
        let code = &file.lines[i].code;
        let justified = comment_in_window(&file.lines, i, PANIC_OK_WINDOW, &["PANIC-OK"]);
        let symbol = super::enclosing_fn(file, i).map_or_else(String::new, |f| f.name.clone());
        // The pull-parser pattern: a file-local `fn expect` makes
        // `self.expect(..)` an ordinary fallible call, not Option::expect.
        let own_expect = file.fns.iter().any(|f| !f.in_test && f.name == "expect");

        if (code.contains(".unwrap()") || (code.contains(".expect(") && !own_expect)) && !justified
        {
            out.push(Finding::new(
                "panic-path",
                "pp-unwrap",
                &file.rel,
                i + 1,
                &symbol,
                "unwrap/expect on a serving path: a poisoned Option/Result kills the \
                 request thread; return a structured error, or justify the invariant \
                 with a `// PANIC-OK:` comment"
                    .to_string(),
            ));
        }

        if PANIC_MACROS.iter().any(|m| has_word_followed_by(code, m, b'!')) && !justified {
            out.push(Finding::new(
                "panic-path",
                "pp-panic",
                &file.rel,
                i + 1,
                &symbol,
                "panic!/unreachable!/todo! on a serving path: convert to a structured \
                 protocol error, or justify with a `// PANIC-OK:` comment"
                    .to_string(),
            ));
        }

        if INDEX_DIRS.iter().any(|d| file.rel.starts_with(d))
            && has_unchecked_index(code)
            && !justified
        {
            out.push(Finding::new(
                "panic-path",
                "pp-index",
                &file.rel,
                i + 1,
                &symbol,
                "unchecked indexing at the tenant boundary: out-of-bounds panics cross \
                 sessions; use get()/split checks, or justify the bound with a \
                 `// PANIC-OK:` comment"
                    .to_string(),
            ));
        }
    }
    out
}

/// `expr[..]` indexing: a `[` whose previous non-space byte ends an
/// expression (identifier, `)`, or `]`). Attributes (`#[`), macro
/// brackets (`vec![`), array types (`: [u8; 4]`), and slice patterns
/// all have non-expression bytes before the bracket.
fn has_unchecked_index(code: &str) -> bool {
    let b = code.as_bytes();
    for (i, &ch) in b.iter().enumerate() {
        if ch != b'[' {
            continue;
        }
        let mut j = i;
        while j > 0 && (b[j - 1] == b' ' || b[j - 1] == b'\t') {
            j -= 1;
        }
        if j == 0 {
            continue;
        }
        let prev = b[j - 1];
        if is_ident_byte(prev) || prev == b')' || prev == b']' {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(sources: &[(&str, &str)]) -> Vec<(String, &'static str, usize, String)> {
        let model = CrateModel::from_sources(sources);
        run(&model).into_iter().map(|f| (f.file, f.rule, f.line, f.symbol)).collect()
    }

    #[test]
    fn unwrap_on_a_serve_path_fires_and_panic_ok_clears_it() {
        let bad = "fn dispatch(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        let got = findings(&[("serve/mod.rs", bad)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].1, "pp-unwrap");
        assert_eq!(got[0].2, 2);
        assert_eq!(got[0].3, "dispatch");

        let good = "fn dispatch(x: Option<u32>) -> u32 {\n    // PANIC-OK: x was checked by the caller one line up.\n    x.unwrap()\n}\n";
        assert!(findings(&[("serve/mod.rs", good)]).is_empty());
    }

    #[test]
    fn reachability_follows_method_calls_out_of_serve() {
        // serve -> (method call) -> api helper with a panic: flagged even
        // though the receiver type is unknown.
        let serve = "fn dispatch(s: S) -> u32 {\n    s.query(1)\n}\n";
        let api = "pub struct S;\nimpl S {\n    pub fn query(&self, x: u32) -> u32 {\n        deep(x)\n    }\n}\nfn deep(x: u32) -> u32 {\n    panic!(\"boom\")\n}\n";
        let island = "pub fn lonely() -> u32 {\n    panic!(\"never reached\")\n}\n";
        let got = findings(&[
            ("serve/mod.rs", serve),
            ("api/session.rs", api),
            ("labelprop/mod.rs", island),
        ]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "api/session.rs");
        assert_eq!(got[0].1, "pp-panic");
        assert_eq!(got[0].3, "deep");
    }

    #[test]
    fn query_root_is_seeded_without_any_serve_caller() {
        let api = "pub struct ImSession;\nimpl ImSession {\n    pub fn query(&self) -> u32 {\n        helper::boom()\n    }\n}\n";
        let helper = "pub fn boom() -> u32 {\n    unreachable!()\n}\n";
        let got = findings(&[("api/session.rs", api), ("util/helper.rs", helper)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "util/helper.rs");
    }

    #[test]
    fn own_expect_method_is_not_option_expect() {
        let json = "pub struct P;\nimpl P {\n    fn expect(&self, b: u8) -> Result<(), ()> { Err(()) }\n    pub fn parse(&self) -> Result<(), ()> {\n        self.expect(b'{')\n    }\n}\n";
        let serve = "fn dispatch(p: P) {\n    let _ = p.parse();\n}\n";
        assert!(findings(&[("serve/mod.rs", serve), ("util/json.rs", json)]).is_empty());
    }

    #[test]
    fn indexing_fires_only_at_the_tenant_boundary() {
        let serve = "fn scan(buf: &[u8], k: usize) -> u8 {\n    buf[k]\n}\n";
        let got = findings(&[("serve/reader.rs", serve)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].1, "pp-index");

        // The same pattern in a kernel file reachable from serve: no
        // pp-index (that boundary is certified by SAFETY/lint rules).
        let serve2 = "fn scan(buf: &[u8], k: usize) -> u8 {\n    simd::row(buf, k)\n}\n";
        let kernel = "pub fn row(buf: &[u8], k: usize) -> u8 {\n    buf[k]\n}\n";
        assert!(findings(&[("serve/reader.rs", serve2), ("simd/mod.rs", kernel)]).is_empty());

        // Attributes, macro brackets, and array types are not indexing.
        let clean = "#[derive(Debug)]\nfn scan() -> Vec<u8> {\n    let a: [u8; 2] = [0, 1];\n    vec![a[0]]\n}\n";
        let got = findings(&[("serve/reader.rs", clean)]);
        assert_eq!(got.len(), 1, "only a[0] inside the macro args: {got:?}");
    }

    #[test]
    fn allowlisted_surfaces_and_test_code_are_exempt() {
        let main = "fn cli(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        let serve = concat!(
            "fn dispatch() {\n    crate::cli(None)\n}\n",
            "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1).unwrap();\n    }\n}\n",
        );
        // main.rs is allowlisted even when reachable from serve.
        let got = findings(&[("serve/mod.rs", serve), ("main.rs", main)]);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn unreached_fns_in_reachable_files_are_not_scanned() {
        // `dead` lives in a serve file, so it IS a root here (every
        // serve fn is). Put it in api/ instead: reachable file, dead fn.
        let serve = "fn dispatch(s: S) {\n    s.live()\n}\n";
        let api = "pub struct S;\nimpl S {\n    pub fn live(&self) {}\n}\npub fn dead() {\n    panic!(\"not on any serving path\")\n}\n";
        let got = findings(&[("serve/mod.rs", serve), ("api/session.rs", api)]);
        assert!(got.is_empty(), "{got:?}");
    }
}
