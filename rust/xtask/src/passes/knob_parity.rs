//! Knob-parity pass: every `RunOptions` field must be threaded through
//! all three user-facing surfaces —
//!
//! * `from_json` (the JSON session/config loader, same file as the
//!   struct),
//! * the CLI builder (`session_options`, which maps parsed args onto
//!   builder calls), and
//! * the coordinator banner (the `"grid geometry: ..."` log line that
//!   makes a run's full configuration reproducible from its log).
//!
//! The last five PRs each hand-threaded a new knob through these
//! surfaces; this pass turns the convention into a gate. When one of
//! the anchors (struct, loader fn, CLI fn, banner) cannot be found the
//! pass fails loudly with `knob-self-check` instead of silently
//! passing — renaming an anchor must break the build, not the gate.

use crate::findings::Finding;
use crate::graph::CrateModel;
use crate::lexer::{has_word, has_word_followed_by};
use crate::parser::{SourceFile, StructItem};

const STRUCT_NAME: &str = "RunOptions";
const LOADER_FN: &str = "from_json";
const CLI_FN: &str = "session_options";
const BANNER_TOKEN: &str = "grid geometry";
/// How far above the banner token line its `format!` may sit.
const BANNER_FORMAT_WINDOW: usize = 3;

fn self_check(msg: String) -> Finding {
    Finding::new("knob-parity", "knob-self-check", "", 0, "", msg)
}

pub(crate) fn run(model: &CrateModel) -> Vec<Finding> {
    let mut out = Vec::new();

    let Some((opt_fi, s)) = find_struct(model, STRUCT_NAME) else {
        out.push(self_check(format!("anchor lost: struct `{STRUCT_NAME}` not found")));
        return out;
    };
    if s.fields.is_empty() {
        out.push(self_check(format!("anchor lost: `{STRUCT_NAME}` has no parsed fields")));
        return out;
    }
    let opts_file = &model.files[opt_fi];

    // Surface 1: the JSON loader, in the same file as the struct.
    match opts_file.fns.iter().find(|f| f.name == LOADER_FN && !f.in_test && f.body.is_some()) {
        None => out.push(self_check(format!(
            "anchor lost: fn `{LOADER_FN}` not found in {}",
            opts_file.rel
        ))),
        Some(fj) => {
            let (lo, hi) = fj.body.unwrap();
            for (field, fline) in &s.fields {
                let present = opts_file.lines[lo..=hi.min(opts_file.lines.len() - 1)]
                    .iter()
                    .any(|l| l.code.contains("opts.") && has_word(&l.code, field));
                if !present {
                    out.push(Finding::new(
                        "knob-parity",
                        "knob-missing-from-json",
                        &opts_file.rel,
                        fline + 1,
                        field,
                        format!("RunOptions field `{field}` is not read by `{LOADER_FN}`"),
                    ));
                }
            }
        }
    }

    // Surface 2: the CLI builder.
    let cli = model.files.iter().find_map(|file| {
        file.fns
            .iter()
            .find(|f| f.name == CLI_FN && !f.in_test && f.body.is_some())
            .map(|f| (file, f))
    });
    match cli {
        None => out.push(self_check(format!("anchor lost: fn `{CLI_FN}` not found"))),
        Some((file, f)) => {
            let (lo, hi) = f.body.unwrap();
            for (field, _) in &s.fields {
                let present = file.lines[lo..=hi.min(file.lines.len() - 1)]
                    .iter()
                    .any(|l| has_word_followed_by(&l.code, field, b'('));
                if !present {
                    out.push(Finding::new(
                        "knob-parity",
                        "knob-missing-cli",
                        &file.rel,
                        f.line + 1,
                        field,
                        format!("RunOptions field `{field}` has no builder call in `{CLI_FN}`"),
                    ));
                }
            }
        }
    }

    // Surface 3: the coordinator banner.
    match find_banner(model) {
        None => out.push(self_check(format!(
            "anchor lost: no non-test line containing \"{BANNER_TOKEN}\""
        ))),
        Some((file, anchor, region)) => {
            for (field, _) in &s.fields {
                // Accept plural spellings — the banner prints the grid
                // axis `orders=` for the `order` knob.
                let plural = format!("{field}s");
                if !has_word(&region, field) && !has_word(&region, &plural) {
                    out.push(Finding::new(
                        "knob-parity",
                        "knob-missing-banner",
                        &file.rel,
                        anchor + 1,
                        field,
                        format!("RunOptions field `{field}` is not printed by the banner"),
                    ));
                }
            }
        }
    }

    out
}

fn find_struct<'m>(model: &'m CrateModel, name: &str) -> Option<(usize, &'m StructItem)> {
    for (fi, file) in model.files.iter().enumerate() {
        if let Some(s) = file.structs.iter().find(|s| s.name == name && !file.mask[s.line]) {
            return Some((fi, s));
        }
    }
    None
}

/// Locate the banner: the first non-test raw line containing
/// [`BANNER_TOKEN`], then the `format!` call it belongs to (within
/// [`BANNER_FORMAT_WINDOW`] lines above), then the paren-balanced
/// extent of that call. Returns the file, the 0-based token line, and
/// the region's raw text — raw, because field names live inside the
/// format string literal, which the lexer blanks from code text.
fn find_banner(model: &CrateModel) -> Option<(&SourceFile, usize, String)> {
    for file in &model.files {
        for i in 0..file.lines.len() {
            if file.mask[i] || !file.raw[i].contains(BANNER_TOKEN) {
                continue;
            }
            let start = (0..=BANNER_FORMAT_WINDOW)
                .filter_map(|d| i.checked_sub(d))
                .find(|&j| file.lines[j].code.contains("format!"))?;
            let end = balance_parens(file, start).unwrap_or(i);
            let region = file.raw[start..=end.min(file.raw.len() - 1)].join("\n");
            return Some((file, i, region));
        }
    }
    None
}

/// From the `format!` occurrence on line `start`, find the line where
/// its parenthesis nesting returns to zero (scanning code text, so
/// parens inside string literals are already blanked).
fn balance_parens(file: &SourceFile, start: usize) -> Option<usize> {
    let mut depth = 0i64;
    let mut opened = false;
    let mut col = file.lines[start].code.find("format!").unwrap_or(0);
    for j in start..file.lines.len() {
        for ch in file.lines[j].code.bytes().skip(col) {
            match ch {
                b'(' => {
                    depth += 1;
                    opened = true;
                }
                b')' => depth -= 1,
                _ => {}
            }
            if opened && depth <= 0 {
                return Some(j);
            }
        }
        col = 0;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parser;
    use std::path::Path;

    const OPTIONS_OK: &str = concat!(
        "pub struct RunOptions {\n",
        "    pub r_count: usize,\n",
        "    pub seed: u64,\n",
        "}\n",
        "pub fn from_json(text: &str) -> RunOptions {\n",
        "    let mut opts = RunOptions::default();\n",
        "    opts.r_count = 1;\n",
        "    opts.seed = 2;\n",
        "    opts\n",
        "}\n",
    );
    const MAIN_OK: &str = concat!(
        "pub fn session_options(args: &Args) -> RunOptions {\n",
        "    RunOptions::default().r_count(args.r).seed(args.s)\n",
        "}\n",
    );
    const COORD_OK: &str = concat!(
        "pub fn banner(cfg: &Cfg) {\n",
        "    log(&format!(\n",
        "        \"grid geometry: r_count={} seeds={}\",\n",
        "        cfg.options.r_count,\n",
        "        cfg.seeds.join(\",\")\n",
        "    ));\n",
        "    let tail = cfg.options.hidden_knob;\n",
        "}\n",
    );

    fn findings(sources: &[(&str, &str)]) -> Vec<(&'static str, String)> {
        let model = CrateModel::from_sources(sources);
        run(&model).into_iter().map(|f| (f.rule, f.symbol)).collect()
    }

    #[test]
    fn full_parity_is_clean_and_plural_banner_spelling_counts() {
        // `seeds={}` in the banner covers the `seed` field via the
        // plural rule — and `hidden_knob` after the banner's close
        // paren must NOT count as banner coverage (region bounding).
        let got = findings(&[
            ("api/options.rs", OPTIONS_OK),
            ("main.rs", MAIN_OK),
            ("coordinator/mod.rs", COORD_OK),
        ]);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn unthreaded_field_fails_all_three_surfaces() {
        let options = OPTIONS_OK.replace(
            "    pub seed: u64,\n",
            "    pub seed: u64,\n    pub hidden_knob: bool,\n",
        );
        let got = findings(&[
            ("api/options.rs", &options),
            ("main.rs", MAIN_OK),
            ("coordinator/mod.rs", COORD_OK),
        ]);
        let rules: Vec<&str> =
            got.iter().filter(|(_, s)| s == "hidden_knob").map(|(r, _)| *r).collect();
        assert!(rules.contains(&"knob-missing-from-json"), "{got:?}");
        assert!(rules.contains(&"knob-missing-cli"), "{got:?}");
        // `hidden_knob` appears in COORD_OK *after* the banner's close
        // paren — the region bound keeps it a finding.
        assert!(rules.contains(&"knob-missing-banner"), "{got:?}");
        assert_eq!(got.len(), 3, "no findings for threaded fields: {got:?}");
    }

    #[test]
    fn partially_threaded_field_fails_only_missing_surfaces() {
        let options = OPTIONS_OK
            .replace("    pub seed: u64,\n", "    pub seed: u64,\n    pub lanes: u8,\n")
            .replace("    opts.seed = 2;\n", "    opts.seed = 2;\n    opts.lanes = 8;\n");
        let main_rs = MAIN_OK.replace(".seed(args.s)", ".seed(args.s).lanes(args.l)");
        let got = findings(&[
            ("api/options.rs", &options),
            ("main.rs", &main_rs),
            ("coordinator/mod.rs", COORD_OK),
        ]);
        assert_eq!(got, vec![("knob-missing-banner", "lanes".to_string())]);
    }

    #[test]
    fn lost_anchors_fail_the_self_check() {
        let no_struct = findings(&[("main.rs", MAIN_OK), ("coordinator/mod.rs", COORD_OK)]);
        assert_eq!(no_struct, vec![("knob-self-check", String::new())]);

        let no_cli =
            findings(&[("api/options.rs", OPTIONS_OK), ("coordinator/mod.rs", COORD_OK)]);
        assert!(no_cli.iter().any(|(r, _)| *r == "knob-self-check"), "{no_cli:?}");

        let no_banner = findings(&[("api/options.rs", OPTIONS_OK), ("main.rs", MAIN_OK)]);
        assert!(no_banner.iter().any(|(r, _)| *r == "knob-self-check"), "{no_banner:?}");
    }

    /// The satellite-(c) property: renaming ANY real `RunOptions` field
    /// must be caught. Exhaustive over the real field list (strictly
    /// stronger than sampling), with an LCG for suffix variety.
    #[test]
    fn renaming_any_real_field_is_caught() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
        let options = std::fs::read_to_string(root.join("api/options.rs")).unwrap();
        let main_rs = std::fs::read_to_string(root.join("main.rs")).unwrap();
        let coord = std::fs::read_to_string(root.join("coordinator/mod.rs")).unwrap();

        let parsed = parser::parse("api/options.rs", &options);
        let s = parsed.structs.iter().find(|s| s.name == STRUCT_NAME).unwrap();
        assert!(s.fields.len() >= 10, "parser must see the real field list: {:?}", s.fields);

        let mut state: u64 = 0x9E37_79B9_7F4A_7C15;
        for (field, fline) in &s.fields {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let renamed = format!("{field}_x{}", state % 97);
            let mut lines: Vec<String> = options.lines().map(|l| l.to_string()).collect();
            // The recorded field line is the declaration itself, where
            // the first occurrence of the name is the field ident.
            lines[*fline] = lines[*fline].replacen(field.as_str(), &renamed, 1);
            let mutated = lines.join("\n");
            let got = findings(&[
                ("api/options.rs", &mutated),
                ("main.rs", &main_rs),
                ("coordinator/mod.rs", &coord),
            ]);
            assert!(
                got.iter().any(|(_, sym)| *sym == renamed),
                "renaming `{field}` -> `{renamed}` went undetected: {got:?}"
            );
        }
    }
}
