//! Lock-discipline pass: derive the lock-acquisition graph from the
//! `runtime::sync` facade `.lock()` sites and enforce a declared total
//! order, checked in as `rust/xtask/lock.order`.
//!
//! **Naming.** A site's lock is named `<module>.<receiver>` where
//! `<module>` is the file path with `.rs` / `/mod.rs` stripped and
//! `<receiver>` is the last identifier before `.lock(` (`self.shared.`
//! `state.lock()` → `runtime/pool.state`; a tuple-field receiver like
//! `self.0` becomes `field0`). Renaming a lock field therefore renames
//! the lock, and the manifest goes stale loudly (`lock-stale-order`).
//!
//! **Held-set tracking** is intraprocedural and syntactic: a guard
//! `let g = recv.lock()` is live from its binding line until the
//! enclosing brace scope closes or an unconditional `drop(g)` at the
//! binding depth; a guard-less `.lock()` temporary lives for its line
//! only. While a guard is live, every further `.lock()` site forms an
//! ordered pair, and every *strictly uniquely resolvable* bare or
//! `Q::`-qualified call
//! ([`CallGraph::resolve_strict`](crate::graph::CallGraph::resolve_strict))
//! contributes the callee's transitive acquisition set. Strict,
//! non-method resolution only — the widen-to-all fallback that is sound
//! for reachability would fabricate acquisition edges here (`File::open`
//! "resolving" to `SessionPool::open`), and method calls are worse
//! still: receiver types are unknown, so `parts.join("; ")` sharing a
//! name with the one crate `fn join` proves nothing. Fabricated edges
//! mean phantom violations, which is exactly the unsound direction for
//! an order checker. The held windows in this crate are small and drop
//! their guards before crossing module boundaries, so the common case
//! (same-fn nesting) is always visible, and the one real
//! interprocedural chain (`SessionPool::open` holding the pool state
//! while `ImSession::prepare` spins up a `WorkerPool`) is all bare or
//! qualified calls.
//!
//! Rules:
//!
//! * `lock-unnamed` — a `.lock()` whose receiver has no identifier to
//!   name the lock by; bind the receiver first.
//! * `lock-undeclared` — a site whose lock name is missing from
//!   `lock.order`.
//! * `lock-stale-order` — a manifest entry matching no site (renamed or
//!   deleted lock).
//! * `lock-order-violation` — a derived pair acquired against the
//!   declared order (or a reentrant self-pair, which self-deadlocks).
//! * `lock-cycle` — a cycle in the derived acquisition graph itself,
//!   reported even when the manifest is empty.

use crate::findings::Finding;
use crate::graph::{CrateModel, Def};
use crate::lexer::is_ident_byte;
use std::collections::{BTreeMap, BTreeSet};

/// The parsed `lock.order` manifest: lock names, most-outer first.
#[derive(Debug, Default)]
pub(crate) struct LockOrder {
    /// `(name, 1-based manifest line)` in declaration order.
    entries: Vec<(String, usize)>,
}

impl LockOrder {
    /// One lock name per line, `#` comments (full-line or trailing) and
    /// blank lines ignored; duplicates are an error.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut entries: Vec<(String, usize)> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(pos) => &raw[..pos],
                None => raw,
            };
            let name = line.trim();
            if name.is_empty() {
                continue;
            }
            if name.split_whitespace().count() != 1 {
                return Err(format!(
                    "lock.order line {}: expected a single lock name, got '{name}'",
                    lineno + 1
                ));
            }
            if entries.iter().any(|(n, _)| n == name) {
                return Err(format!("lock.order line {}: duplicate lock '{name}'", lineno + 1));
            }
            entries.push((name.to_string(), lineno + 1));
        }
        Ok(Self { entries })
    }

    /// Load from a path; a missing file is an empty manifest (every
    /// site then reports `lock-undeclared`, so absence fails loudly).
    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Self::parse(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Self::default()),
            Err(e) => Err(format!("read {}: {e}", path.display())),
        }
    }

    fn position(&self, name: &str) -> Option<usize> {
        self.entries.iter().position(|(n, _)| n == name)
    }
}

/// One discovered `.lock()` acquisition site.
#[derive(Debug, Clone)]
struct Site {
    file: usize,
    /// 0-based line.
    line: usize,
    /// Derived lock name, or `None` when the receiver is unnameable.
    name: Option<String>,
}

/// One derived ordered acquisition: `held` was live when `then` was
/// acquired at `file:line`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Pair {
    held: String,
    then: String,
    file: usize,
    /// 0-based line of the second acquisition.
    line: usize,
}

fn module_key(rel: &str) -> String {
    rel.strip_suffix("/mod.rs")
        .or_else(|| rel.strip_suffix(".rs"))
        .unwrap_or(rel)
        .to_string()
}

/// Last identifier before `.lock(` starting at byte `dot` (the `.`).
fn receiver_name(code: &str, dot: usize) -> Option<String> {
    let b = code.as_bytes();
    let end = dot;
    let mut start = end;
    while start > 0 && is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    if start == end {
        return None;
    }
    let ident = &code[start..end];
    if ident.bytes().all(|c| c.is_ascii_digit()) {
        Some(format!("field{ident}"))
    } else {
        Some(ident.to_string())
    }
}

/// All `.lock(` occurrences on one code line: byte offsets of the `.`.
fn lock_dots(code: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(".lock(") {
        let dot = from + pos;
        out.push(dot);
        from = dot + ".lock".len();
    }
    out
}

fn discover_sites(model: &CrateModel) -> Vec<Site> {
    let mut out = Vec::new();
    for (fi, file) in model.files.iter().enumerate() {
        for (i, line) in file.lines.iter().enumerate() {
            if file.mask[i] {
                continue;
            }
            for dot in lock_dots(&line.code) {
                let name = receiver_name(&line.code, dot)
                    .map(|r| format!("{}.{r}", module_key(&file.rel)));
                out.push(Site { file: fi, line: i, name });
            }
        }
    }
    out
}

/// Direct lock names acquired inside each fn body (nested-fn lines are
/// attributed to the enclosing fn too — over-approximate, like the
/// parser itself).
fn direct_acquires(model: &CrateModel, sites: &[Site]) -> BTreeMap<Def, BTreeSet<String>> {
    let mut out: BTreeMap<Def, BTreeSet<String>> = BTreeMap::new();
    for (fi, file) in model.files.iter().enumerate() {
        for (ki, f) in file.fns.iter().enumerate() {
            if f.in_test {
                continue;
            }
            let Some((lo, hi)) = f.body else { continue };
            let def = Def::Parsed { file: fi, fn_idx: ki };
            let names: BTreeSet<String> = sites
                .iter()
                .filter(|s| s.file == fi && s.line >= lo && s.line <= hi)
                .filter_map(|s| s.name.clone())
                .collect();
            if !names.is_empty() {
                out.insert(def, names);
            }
        }
    }
    out
}

/// Transitive closure of `direct_acquires` over uniquely-resolving
/// calls (`lock` itself excluded: a `.lock()` call *is* a site, not a
/// propagation edge).
fn transitive_acquires(
    model: &CrateModel,
    cg: &crate::graph::CallGraph<'_>,
    direct: BTreeMap<Def, BTreeSet<String>>,
) -> BTreeMap<Def, BTreeSet<String>> {
    let mut acq = direct;
    loop {
        let mut grew = false;
        for (fi, file) in model.files.iter().enumerate() {
            for (ki, f) in file.fns.iter().enumerate() {
                if f.in_test {
                    continue;
                }
                let def = Def::Parsed { file: fi, fn_idx: ki };
                let mut add: BTreeSet<String> = BTreeSet::new();
                for call in &f.calls {
                    // `.lock()` calls ARE sites, not propagation edges;
                    // method calls are untrusted entirely — a receiver's
                    // type is unknown, so `xs.join(", ")` or `.map(..)`
                    // sharing a name with one crate fn proves nothing.
                    if call.name == "lock" || call.is_method {
                        continue;
                    }
                    if let Some(target) = cg.resolve_strict(def, call) {
                        if let Some(names) = acq.get(&target) {
                            add.extend(names.iter().cloned());
                        }
                    }
                }
                if !add.is_empty() {
                    let entry = acq.entry(def).or_default();
                    let before = entry.len();
                    entry.extend(add);
                    grew |= entry.len() != before;
                }
            }
        }
        if !grew {
            return acq;
        }
    }
}

/// One live guard during the body walk.
struct Guard {
    /// Binding variable, when the site was a `let` binding.
    var: Option<String>,
    name: String,
    /// Brace depth (relative to the body walk) at the binding line's
    /// start; the guard dies when depth dips below this.
    depth: i64,
}

/// `let [mut] IDENT = ...` binding variable, if this line is one and
/// the `=` comes before `col`.
fn binding_var(code: &str, col: usize) -> Option<String> {
    let eq = code.find('=')?;
    if eq > col {
        return None;
    }
    let head = code[..eq].trim();
    let rest = head.strip_prefix("let")?;
    if !rest.starts_with(|c: char| c.is_whitespace()) {
        return None;
    }
    let rest = rest.trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let b = rest.as_bytes();
    let mut end = 0;
    while end < b.len() && is_ident_byte(b[end]) {
        end += 1;
    }
    if end == 0 {
        return None; // tuple/struct pattern: no single guard variable
    }
    let after = rest[end..].trim_start();
    (after.is_empty() || after.starts_with(':')).then(|| rest[..end].to_string())
}

/// Walk one fn body tracking live guards; record ordered pairs.
#[allow(clippy::too_many_arguments)]
fn walk_body(
    model: &CrateModel,
    cg: &crate::graph::CallGraph<'_>,
    acquires: &BTreeMap<Def, BTreeSet<String>>,
    sites: &[Site],
    fi: usize,
    ki: usize,
    pairs: &mut BTreeSet<Pair>,
) {
    let file = &model.files[fi];
    let f = &file.fns[ki];
    let Some((lo, hi)) = f.body else { return };
    let def = Def::Parsed { file: fi, fn_idx: ki };
    let mut depth = 0i64;
    let mut guards: Vec<Guard> = Vec::new();
    for i in lo..=hi.min(file.lines.len() - 1) {
        let code = &file.lines[i].code;

        // Unconditional drop(g) at the binding depth releases the guard.
        if let Some(pos) = code.find("drop(") {
            let arg: String = code[pos + 5..]
                .bytes()
                .take_while(|&b| is_ident_byte(b))
                .map(char::from)
                .collect();
            guards.retain(|g| {
                !(g.depth == depth && g.var.as_deref() == Some(arg.as_str()) && !arg.is_empty())
            });
        }

        // New acquisition sites on this line.
        for site in sites.iter().filter(|s| s.file == fi && s.line == i) {
            let Some(name) = &site.name else { continue };
            for g in &guards {
                pairs.insert(Pair { held: g.name.clone(), then: name.clone(), file: fi, line: i });
            }
            if let Some(dot) = lock_dots(code).first().copied() {
                if let Some(var) = binding_var(code, dot) {
                    guards.push(Guard { var: Some(var), name: name.clone(), depth });
                }
            }
        }

        // Calls made while a guard is held contribute the callee's
        // transitive acquisitions — unique resolutions only.
        if !guards.is_empty() {
            for call in
                f.calls.iter().filter(|c| c.line == i && c.name != "lock" && !c.is_method)
            {
                let Some(target) = cg.resolve_strict(def, call) else { continue };
                let Some(names) = acquires.get(&target) else { continue };
                for g in &guards {
                    for name in names {
                        pairs.insert(Pair {
                            held: g.name.clone(),
                            then: name.clone(),
                            file: fi,
                            line: i,
                        });
                    }
                }
            }
        }

        // Scope exit: a dip below the binding depth kills the guard
        // (`}`, `} else {`, `};` all dip mid-line).
        let mut min_depth = depth;
        for ch in code.bytes() {
            match ch {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    min_depth = min_depth.min(depth);
                }
                _ => {}
            }
        }
        guards.retain(|g| min_depth >= g.depth);
    }
}

pub(crate) fn run(model: &CrateModel, order: &LockOrder) -> Vec<Finding> {
    let cg = model.call_graph();
    let sites = discover_sites(model);
    let acquires = transitive_acquires(model, &cg, direct_acquires(model, &sites));

    let mut out = Vec::new();
    let mut seen_names: BTreeSet<&str> = BTreeSet::new();
    for site in &sites {
        let rel = &model.files[site.file].rel;
        match &site.name {
            None => out.push(Finding::new(
                "lock-discipline",
                "lock-unnamed",
                rel,
                site.line + 1,
                "",
                "cannot derive a lock name for this `.lock()` (no receiver identifier); \
                 bind the receiver to a named local first"
                    .to_string(),
            )),
            Some(name) => {
                seen_names.insert(name);
                if order.position(name).is_none() {
                    out.push(Finding::new(
                        "lock-discipline",
                        "lock-undeclared",
                        rel,
                        site.line + 1,
                        name,
                        format!(
                            "lock `{name}` is not declared in xtask/lock.order; add it at \
                             the position matching its acquisition order"
                        ),
                    ));
                }
            }
        }
    }

    for (name, lineno) in &order.entries {
        if !seen_names.contains(name.as_str()) {
            out.push(Finding::new(
                "lock-discipline",
                "lock-stale-order",
                "lock.order",
                *lineno,
                name,
                format!(
                    "manifest lock `{name}` matches no `.lock()` site — the lock was \
                     renamed or removed; update xtask/lock.order"
                ),
            ));
        }
    }

    let mut pairs: BTreeSet<Pair> = BTreeSet::new();
    for (fi, file) in model.files.iter().enumerate() {
        for ki in 0..file.fns.len() {
            if !file.fns[ki].in_test {
                walk_body(model, &cg, &acquires, &sites, fi, ki, &mut pairs);
            }
        }
    }

    for p in &pairs {
        let rel = &model.files[p.file].rel;
        if p.held == p.then {
            out.push(Finding::new(
                "lock-discipline",
                "lock-order-violation",
                rel,
                p.line + 1,
                &p.then,
                format!("reentrant acquisition of `{}` while already held: self-deadlock", p.then),
            ));
            continue;
        }
        if let (Some(a), Some(b)) = (order.position(&p.held), order.position(&p.then)) {
            if a > b {
                out.push(Finding::new(
                    "lock-discipline",
                    "lock-order-violation",
                    rel,
                    p.line + 1,
                    &p.then,
                    format!(
                        "`{}` acquired while holding `{}`, against the declared order in \
                         xtask/lock.order (a concurrent thread taking them in manifest \
                         order deadlocks)",
                        p.then, p.held
                    ),
                ));
            }
        }
    }

    // Cycle detection over the derived graph, independent of the
    // manifest: held → then edges; a back edge is a potential deadlock.
    let mut edges: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for p in &pairs {
        edges.entry(&p.held).or_default().insert(&p.then);
    }
    for cyc in find_cycles(&edges) {
        // Anchor on a pair belonging to the cycle's first edge.
        let anchor = pairs
            .iter()
            .find(|p| p.held == cyc[0] && cyc.contains(&p.then))
            .expect("cycle edges come from pairs");
        out.push(Finding::new(
            "lock-discipline",
            "lock-cycle",
            &model.files[anchor.file].rel,
            anchor.line + 1,
            &cyc[0],
            format!("cyclic lock acquisition: {}", cyc.join(" -> ")),
        ));
    }
    out
}

/// Minimal cycle enumeration: DFS from each node, reporting each cycle
/// once by its lexicographically-smallest member.
fn find_cycles(edges: &BTreeMap<&str, BTreeSet<&str>>) -> Vec<Vec<String>> {
    let mut cycles: BTreeSet<Vec<String>> = BTreeSet::new();
    for &start in edges.keys() {
        let mut stack: Vec<(&str, Vec<&str>)> = vec![(start, vec![start])];
        while let Some((node, path)) = stack.pop() {
            let Some(nexts) = edges.get(node) else { continue };
            for &next in nexts {
                if next == start {
                    // Canonical rotation: smallest member first.
                    if path.iter().min() == Some(&start) {
                        let mut cyc: Vec<String> =
                            path.iter().map(|s| s.to_string()).collect();
                        cyc.push(start.to_string());
                        cycles.insert(cyc);
                    }
                } else if !path.contains(&next) {
                    let mut p = path.clone();
                    p.push(next);
                    stack.push((next, p));
                }
            }
        }
    }
    cycles.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(sources: &[(&str, &str)], order: &str) -> Vec<(&'static str, String, usize)> {
        let model = CrateModel::from_sources(sources);
        let order = LockOrder::parse(order).unwrap();
        run(&model, &order).into_iter().map(|f| (f.rule, f.symbol, f.line)).collect()
    }

    const POOL: &str = concat!(
        "pub struct Pool { state: Mutex<u32>, session: Mutex<u32> }\n",
        "impl Pool {\n",
        "    pub fn query(&self) {\n",
        "        let st = self.state.lock();\n",
        "        let s = self.session.lock();\n",
        "        drop(s);\n",
        "        drop(st);\n",
        "    }\n",
        "}\n",
    );

    #[test]
    fn sites_are_named_and_undeclared_locks_fire() {
        let got = findings(&[("serve/pool.rs", POOL)], "serve/pool.state\n");
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0], ("lock-undeclared", "serve/pool.session".to_string(), 5));
    }

    #[test]
    fn declared_order_accepts_and_reversal_fires() {
        let ok = "serve/pool.state\nserve/pool.session\n";
        assert!(findings(&[("serve/pool.rs", POOL)], ok).is_empty());

        let reversed = "serve/pool.session\nserve/pool.state\n";
        let got = findings(&[("serve/pool.rs", POOL)], reversed);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "lock-order-violation");
        assert_eq!(got[0].1, "serve/pool.session");
    }

    #[test]
    fn stale_manifest_entries_fire_with_their_line() {
        let order = "# comment\nserve/pool.state\nserve/pool.session\nserve/pool.ghost\n";
        let got = findings(&[("serve/pool.rs", POOL)], order);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0], ("lock-stale-order", "serve/pool.ghost".to_string(), 4));
    }

    #[test]
    fn dropping_or_closing_scope_releases_the_guard() {
        // state dropped (at binding depth) before session: no pair.
        let drop_first = concat!(
            "pub fn query(p: &Pool) {\n",
            "    let st = p.state.lock();\n",
            "    drop(st);\n",
            "    let s = p.session.lock();\n",
            "    drop(s);\n",
            "}\n",
        );
        // Reversed order declared: a pair would fire, so emptiness
        // proves the pair never formed.
        let order = "serve/pool.session\nserve/pool.state\n";
        assert!(findings(&[("serve/pool.rs", drop_first)], order).is_empty());

        let scope_first = concat!(
            "pub fn query(p: &Pool) {\n",
            "    let id = {\n",
            "        let st = p.state.lock();\n",
            "        7\n",
            "    };\n",
            "    let s = p.session.lock();\n",
            "    drop((id, s));\n",
            "}\n",
        );
        assert!(findings(&[("serve/pool.rs", scope_first)], order).is_empty());

        // A conditional drop (deeper than the binding) does NOT release.
        let cond_drop = concat!(
            "pub fn query(p: &Pool, b: bool) {\n",
            "    let st = p.state.lock();\n",
            "    if b {\n",
            "        drop(st);\n",
            "    }\n",
            "    let s = p.session.lock();\n",
            "    drop(s);\n",
            "}\n",
        );
        let got = findings(&[("serve/pool.rs", cond_drop)], order);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "lock-order-violation");
    }

    #[test]
    fn reentrant_acquisition_is_a_violation_even_when_declared() {
        let reentrant = concat!(
            "pub fn tick(p: &Pool) {\n",
            "    let a = p.state.lock();\n",
            "    let b = p.state.lock();\n",
            "    drop((a, b));\n",
            "}\n",
        );
        let got = findings(&[("serve/pool.rs", reentrant)], "serve/pool.state\n");
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "lock-order-violation");
        assert!(got[0].1.contains("state"));
    }

    #[test]
    fn interprocedural_acquisition_through_unique_calls() {
        // query holds state and calls prepare(), which (transitively,
        // through worker()) locks jobs — order declared jobs-first, so
        // the derived pair violates.
        let serve = concat!(
            "pub fn query(p: &Pool) {\n",
            "    let st = p.state.lock();\n",
            "    crate::runtime::prepare();\n",
            "    drop(st);\n",
            "}\n",
        );
        let runtime = concat!(
            "pub fn prepare() {\n",
            "    worker()\n",
            "}\n",
            "fn worker() {\n",
            "    let j = self_jobs().jobs.lock();\n",
            "    drop(j);\n",
            "}\n",
        );
        let order = "runtime/pool.jobs\nserve/pool.state\n";
        let got = findings(&[("serve/pool.rs", serve), ("runtime/pool.rs", runtime)], order);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "lock-order-violation");
        assert_eq!(got[0].1, "runtime/pool.jobs");
        assert_eq!(got[0].2, 3, "anchored at the call site");

        // Same shape with the consistent order: clean.
        let ok = "serve/pool.state\nruntime/pool.jobs\n";
        assert!(findings(&[("serve/pool.rs", serve), ("runtime/pool.rs", runtime)], ok)
            .is_empty());
    }

    #[test]
    fn method_calls_do_not_fabricate_edges() {
        // `parts.join("; ")` is a slice method, but the crate has
        // exactly one `fn join` — which locks. Method calls must not
        // propagate acquisitions, or this would be a phantom reentrant
        // self-pair.
        let model_src = concat!(
            "pub fn drive(sched: &S) {\n",
            "    let st = sched.q.lock();\n",
            "    let parts: Vec<String> = vec![];\n",
            "    let _msg = parts.join(\"; \");\n",
            "    drop(st);\n",
            "}\n",
            "pub fn join(sched: &S) {\n",
            "    let st = sched.q.lock();\n",
            "    drop(st);\n",
            "}\n",
        );
        let got = findings(&[("runtime/sync/model.rs", model_src)], "runtime/sync/model.q\n");
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn ambiguous_calls_do_not_fabricate_edges() {
        // Two defs named `prepare`: resolution is ambiguous, so no
        // acquisition propagates and no violation fires.
        let serve = concat!(
            "pub fn query(p: &Pool) {\n",
            "    let st = p.state.lock();\n",
            "    ambiguous_prepare();\n",
            "    drop(st);\n",
            "}\n",
        );
        let a = "pub fn ambiguous_prepare() {\n    let j = jobs_of().jobs.lock();\n    drop(j);\n}\n";
        let b = "pub fn ambiguous_prepare() {}\n";
        let order = "runtime/pool.jobs\nserve/pool.state\nutil/x.jobs\n";
        let got = findings(
            &[("serve/pool.rs", serve), ("runtime/pool.rs", a), ("util/other.rs", b)],
            order,
        );
        // Only the stale entry for util/x.jobs (declared, never seen).
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "lock-stale-order");
    }

    #[test]
    fn cycles_are_reported_even_without_a_manifest() {
        let ab = concat!(
            "pub fn forward(p: &P) {\n",
            "    let a = p.alpha.lock();\n",
            "    let b = p.beta.lock();\n",
            "    drop((a, b));\n",
            "}\n",
            "pub fn backward(p: &P) {\n",
            "    let b = p.beta.lock();\n",
            "    let a = p.alpha.lock();\n",
            "    drop((a, b));\n",
            "}\n",
        );
        let got = findings(&[("runtime/pool.rs", ab)], "");
        let rules: Vec<&str> = got.iter().map(|(r, _, _)| *r).collect();
        assert!(rules.contains(&"lock-cycle"), "{got:?}");
        // Both sites also report lock-undeclared with the empty manifest.
        assert!(rules.contains(&"lock-undeclared"), "{got:?}");
    }

    #[test]
    fn tuple_field_receivers_get_stable_names() {
        let shim = "pub fn lock_shim(m: &M) {\n    let g = m.0.lock();\n    drop(g);\n}\n";
        let got = findings(&[("runtime/sync/mod.rs", shim)], "");
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].1, "runtime/sync.field0");
    }

    #[test]
    fn manifest_parser_rejects_duplicates_and_multiword_lines() {
        assert!(LockOrder::parse("a.x\nb.y\na.x\n").is_err());
        assert!(LockOrder::parse("a.x b.y\n").is_err());
        let ok = LockOrder::parse("# c\na.x # trailing\n\nb.y\n").unwrap();
        assert_eq!(ok.position("a.x"), Some(0));
        assert_eq!(ok.position("b.y"), Some(1));
    }
}
