//! Determinism pass: the paper's headline invariant is that seeds and
//! σ̂ are bit-identical across lanes, orderings, schedules, and stores,
//! so anything order- or time-dependent on a kernel/algorithm path is a
//! hazard. This pass flags, on every file reachable from the kernel
//! entry modules:
//!
//! * `det-hash-iter` — `HashMap`/`HashSet` use (iteration order is
//!   randomized per process since `RandomState` seeds from the OS);
//! * `det-wall-clock` — `Instant::now` / `SystemTime` / thread-identity
//!   reads (`RandomState` construction counts too);
//! * `det-float-reduce` — float `.sum()`/`.fold()` inside a function
//!   that also drives parallel execution: float addition is not
//!   associative, so reduction order must be documented.
//!
//! A hazard is accepted when a `DETERMINISM:` comment within
//! [`DETERMINISM_WINDOW`] lines above justifies it (mirroring the
//! SAFETY/ORDERING conventions), or when the file is an allowlisted
//! I/O / orchestration module whose output never feeds σ̂.

use crate::findings::Finding;
use crate::graph::CrateModel;
use crate::lexer::{comment_in_window, has_word};
use crate::parser::SourceFile;

/// How many lines above a hazard the `DETERMINISM:` comment may sit.
pub(crate) const DETERMINISM_WINDOW: usize = 10;

/// Kernel/algorithm entry modules: reachability roots.
const ROOT_DIRS: [&str; 12] = [
    "algo/", "api/", "labelprop/", "sampling/", "simd/", "rr/", "sketch/", "gen/", "graph/",
    "rng/", "hash/", "runtime/",
];

/// I/O-only and orchestration modules: their timing/ordering never
/// reaches seed selection or σ̂.
const ALLOW_FILES: [&str; 4] = ["main.rs", "bench.rs", "util/timer.rs", "util/args.rs"];
const ALLOW_DIRS: [&str; 3] = ["coordinator/", "config/", "serve/"];

/// Tokens marking a function as driving parallel execution.
const PARALLEL_TOKENS: [&str; 5] =
    ["parallel_for", "parallel_region", "WorkerPool", "spawn", "par_iter"];

fn allowlisted(rel: &str) -> bool {
    ALLOW_FILES.contains(&rel) || ALLOW_DIRS.iter().any(|d| rel.starts_with(d))
}

fn is_root(f: &SourceFile) -> bool {
    ROOT_DIRS.iter().any(|d| f.rel.starts_with(d))
}

pub(crate) fn run(model: &CrateModel) -> Vec<Finding> {
    // Scope: call-graph reachability from the kernel entry modules,
    // widened with the module graph (a parent's declared children are
    // analyzed even when every call into them is through trait objects
    // the call graph cannot see).
    let mut scope = model.reachable_files(is_root);
    loop {
        let mut grew = false;
        for idx in scope.clone() {
            for child in model.module_children(idx) {
                grew |= scope.insert(child);
            }
        }
        if !grew {
            break;
        }
    }
    let mut out = Vec::new();
    for &idx in &scope {
        let file = &model.files[idx];
        if allowlisted(&file.rel) {
            continue;
        }
        scan_file(file, &mut out);
    }
    out
}

fn justified(file: &SourceFile, i: usize) -> bool {
    comment_in_window(&file.lines, i, DETERMINISM_WINDOW, &["DETERMINISM"])
}

fn symbol_at(file: &SourceFile, i: usize) -> String {
    super::enclosing_fn(file, i).map_or_else(String::new, |f| f.name.clone())
}

fn scan_file(file: &SourceFile, out: &mut Vec<Finding>) {
    for i in 0..file.lines.len() {
        if file.mask[i] {
            continue;
        }
        let code = &file.lines[i].code;

        // det-hash-iter: flag uses, not imports — an import alone has no
        // iteration order, and flagging it would double-report.
        if (has_word(code, "HashMap") || has_word(code, "HashSet"))
            && !code.trim_start().starts_with("use ")
            && !justified(file, i)
        {
            out.push(Finding::new(
                "determinism",
                "det-hash-iter",
                &file.rel,
                i + 1,
                &symbol_at(file, i),
                "HashMap/HashSet on a kernel path: iteration order is process-random; \
                 use BTreeMap/BTreeSet, sort before iterating, or justify with a \
                 `// DETERMINISM:` comment"
                    .to_string(),
            ));
        }

        if (code.contains("Instant::now")
            || has_word(code, "SystemTime")
            || code.contains("thread::current")
            || has_word(code, "RandomState"))
            && !justified(file, i)
        {
            out.push(Finding::new(
                "determinism",
                "det-wall-clock",
                &file.rel,
                i + 1,
                &symbol_at(file, i),
                "wall-clock/thread-identity read on a kernel path: results become \
                 timing-dependent; justify with a `// DETERMINISM:` comment or move \
                 it to an allowlisted module"
                    .to_string(),
            ));
        }

        // det-float-reduce: a reduction whose accumulator type is a
        // float, in a function that also drives parallel execution.
        // Sequential reductions are fine (their order is fixed by the
        // iterator), and so is the documented exact-integer pattern —
        // `.sum::<i64>() as f64` keeps the reduction associative and
        // only converts the exact total.
        if (code.contains(".sum::<f32") || code.contains(".sum::<f64")
            || (code.contains(".fold(") && (has_word(code, "f32") || has_word(code, "f64"))))
            && !justified(file, i)
        {
            let parallel = super::enclosing_fn(file, i).is_some_and(|f| {
                let (lo, hi) = f.body.unwrap_or((f.line, f.line));
                file.lines[lo..=hi.min(file.lines.len() - 1)]
                    .iter()
                    .any(|l| PARALLEL_TOKENS.iter().any(|t| has_word(&l.code, t)))
            });
            if parallel {
                out.push(Finding::new(
                    "determinism",
                    "det-float-reduce",
                    &file.rel,
                    i + 1,
                    &symbol_at(file, i),
                    "float reduction in a parallel-driving function: float addition is \
                     not associative, so the reduction order must be documented with a \
                     `// DETERMINISM:` comment (or use the exact-integer pattern)"
                        .to_string(),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(sources: &[(&str, &str)]) -> Vec<(String, &'static str, String)> {
        let model = CrateModel::from_sources(sources);
        run(&model).into_iter().map(|f| (f.file, f.rule, f.symbol)).collect()
    }

    #[test]
    fn hash_iter_fires_and_determinism_comment_clears_it() {
        let bad = "pub fn remap_ids() {\n    let mut m = std::collections::HashMap::<u64, u32>::new();\n    m.insert(1, 2);\n}\n";
        let got = findings(&[("graph/io.rs", bad)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].1, "det-hash-iter");
        assert_eq!(got[0].2, "remap_ids");

        let good = "pub fn remap_ids() {\n    // DETERMINISM: insert-only membership set; iteration order never observed.\n    let mut m = std::collections::HashMap::<u64, u32>::new();\n    m.insert(1, 2);\n}\n";
        assert!(findings(&[("graph/io.rs", good)]).is_empty());

        let btree = "pub fn remap_ids() {\n    let mut m = std::collections::BTreeMap::<u64, u32>::new();\n    m.insert(1, 2);\n}\n";
        assert!(findings(&[("graph/io.rs", btree)]).is_empty());
    }

    #[test]
    fn imports_and_test_code_are_exempt() {
        let text = concat!(
            "use std::collections::HashMap;\n",
            "pub fn touch() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() {\n",
            "        let mut m = std::collections::HashSet::new();\n",
            "        m.insert(1);\n",
            "    }\n",
            "}\n",
        );
        assert!(findings(&[("hash/mod.rs", text)]).is_empty());
    }

    #[test]
    fn allowlisted_modules_are_skipped_even_when_reachable() {
        let serve = "pub fn tick() {\n    let m = std::collections::HashMap::<u32, u32>::new();\n    let _ = (m, std::time::Instant::now());\n}\n";
        let entry = "pub fn entry() { crate::serve::tick() }\n";
        assert!(findings(&[("algo/mod.rs", entry), ("serve/mod.rs", serve)]).is_empty());
    }

    #[test]
    fn reachability_pulls_in_helpers_but_not_islands() {
        let entry = "pub fn entry() {\n    helper::go()\n}\n";
        let helper = "pub fn go() {\n    let m = std::collections::HashMap::<u32, u32>::new();\n    drop(m);\n}\n";
        let island = "pub fn lonely() {\n    let m = std::collections::HashMap::<u32, u32>::new();\n    drop(m);\n}\n";
        let got = findings(&[
            ("algo/mod.rs", entry),
            ("util/helper.rs", helper),
            ("util/island.rs", island),
        ]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "util/helper.rs");
    }

    #[test]
    fn wall_clock_fires_and_comment_clears_it() {
        let bad = "pub fn exceeded() -> bool {\n    std::time::Instant::now().elapsed().as_secs() > 1\n}\n";
        let got = findings(&[("algo/mod.rs", bad)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].1, "det-wall-clock");

        let good = "pub fn exceeded() -> bool {\n    // DETERMINISM: budgets are an explicit outcome axis, not part of seed determinism.\n    std::time::Instant::now().elapsed().as_secs() > 1\n}\n";
        assert!(findings(&[("algo/mod.rs", good)]).is_empty());
    }

    #[test]
    fn float_reduce_fires_only_in_parallel_functions() {
        let bad = concat!(
            "pub fn par_sigma(xs: &[f32], pool: &WorkerPool) -> f32 {\n",
            "    pool.parallel_for(xs.len(), |_| {});\n",
            "    xs.iter().map(|x| *x).sum::<f32>()\n",
            "}\n",
        );
        let got = findings(&[("sampling/mod.rs", bad)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].1, "det-float-reduce");

        let sequential = "pub fn sigma(xs: &[f32]) -> f32 {\n    xs.iter().map(|x| *x).sum::<f32>()\n}\n";
        assert!(findings(&[("sampling/mod.rs", sequential)]).is_empty());

        let documented = concat!(
            "pub fn par_sigma(xs: &[f32], pool: &WorkerPool) -> f32 {\n",
            "    pool.parallel_for(xs.len(), |_| {});\n",
            "    // DETERMINISM: reduced sequentially on the coordinator thread, fixed order.\n",
            "    xs.iter().map(|x| *x).sum::<f32>()\n",
            "}\n",
        );
        assert!(findings(&[("sampling/mod.rs", documented)]).is_empty());
    }

    #[test]
    fn module_graph_widens_scope_to_declared_children() {
        // `util/helper.rs` becomes reachable through a call edge; its
        // child `util/helper/sub.rs` has no call edge at all — only the
        // `mod sub;` declaration — yet is still analyzed.
        let entry = "pub fn entry() {\n    helper::go()\n}\n";
        let parent = "mod sub;\npub fn go() {}\n";
        let child = "pub fn build() {\n    let m = std::collections::HashMap::<u32, u32>::new();\n    drop(m);\n}\n";
        let got = findings(&[
            ("algo/mod.rs", entry),
            ("util/helper.rs", parent),
            ("util/helper/sub.rs", child),
        ]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "util/helper/sub.rs");
    }
}
