//! Unsafe-boundary pass for the `simd/` kernels.
//!
//! Two rules:
//!
//! * `unsafe-no-safety-doc` — every `unsafe fn` in `simd/` (including
//!   the `macro_rules!` templates that generate the AVX2 kernels) must
//!   carry a `# Safety` doc section stating its preconditions.
//! * `unsafe-call-unguarded` — every non-test call to one of those
//!   functions (under its own name or a `pub use ... as` alias) must
//!   sit within a few lines of (a) a `SAFETY:` comment restating the
//!   preconditions and (b) evidence of CPU feature detection
//!   (`is_x86_feature_detected!`, `#[target_feature]`, or a
//!   "…after detection" argument).
//!
//! The call scan covers the whole crate, not just `simd/` — an
//! unguarded caller in `labelprop/` is exactly the bug this pass
//! exists to catch.

use crate::findings::Finding;
use crate::graph::CrateModel;
use crate::lexer::{comment_in_window, is_ident_byte};
use std::collections::BTreeSet;

/// How far above an `unsafe fn` its `# Safety` doc may sit.
const SAFETY_DOC_WINDOW: usize = 12;
/// How far above a call site its SAFETY comment / guard may sit.
const GUARD_WINDOW: usize = 8;
/// Lower-cased tokens accepted as evidence of feature detection.
const GUARD_TOKENS: [&str; 3] = ["detect", "is_x86_feature_detected", "target_feature"];

pub(crate) fn run(model: &CrateModel) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut unsafe_names: BTreeSet<String> = BTreeSet::new();

    // Collect the unsafe surface of simd/ and check `# Safety` docs.
    for file in &model.files {
        if !file.rel.starts_with("simd/") {
            continue;
        }
        for f in &file.fns {
            if !f.is_unsafe || f.in_test {
                continue;
            }
            unsafe_names.insert(f.name.clone());
            if !comment_in_window(&file.lines, f.line, SAFETY_DOC_WINDOW, &["# Safety"]) {
                out.push(Finding::new(
                    "unsafe-boundary",
                    "unsafe-no-safety-doc",
                    &file.rel,
                    f.line + 1,
                    &f.name,
                    format!("unsafe fn `{}` has no `# Safety` doc section", f.name),
                ));
            }
        }
        for mac in &file.macros {
            for &l in &mac.unsafe_fn_lines {
                if !comment_in_window(&file.lines, l, SAFETY_DOC_WINDOW, &["# Safety"]) {
                    let generates: Vec<&str> = file
                        .generated
                        .iter()
                        .filter(|g| g.macro_name == mac.name && g.template_line == l)
                        .map(|g| g.name.as_str())
                        .collect();
                    let detail = if generates.is_empty() {
                        String::new()
                    } else {
                        format!(" (generates {})", generates.join(", "))
                    };
                    out.push(Finding::new(
                        "unsafe-boundary",
                        "unsafe-no-safety-doc",
                        &file.rel,
                        l + 1,
                        &mac.name,
                        format!(
                            "unsafe fn template in macro `{}`{detail} has no `# Safety` doc section",
                            mac.name
                        ),
                    ));
                }
            }
        }
        for g in &file.generated {
            // parse_generated only records invocations of macros whose
            // bodies declare `unsafe fn`, so every generated name is an
            // unsafe entry point.
            unsafe_names.insert(g.name.clone());
        }
    }

    // Close over `use ... as` aliases (anywhere in the crate).
    loop {
        let mut grew = false;
        for file in &model.files {
            for (target, alias) in &file.aliases {
                if unsafe_names.contains(target) && !unsafe_names.contains(alias) {
                    unsafe_names.insert(alias.clone());
                    grew = true;
                }
            }
        }
        if !grew {
            break;
        }
    }

    // Scan every non-test line in the crate for calls.
    for file in &model.files {
        for (i, line) in file.lines.iter().enumerate() {
            if file.mask[i] {
                continue;
            }
            for name in &unsafe_names {
                if !is_call_line(&line.code, name) {
                    continue;
                }
                let lo = i.saturating_sub(GUARD_WINDOW);
                let window = &file.lines[lo..=i];
                let has_safety = window.iter().any(|l| l.comment.contains("SAFETY"));
                let has_guard = window.iter().any(|l| {
                    let t = format!("{} {}", l.code, l.comment).to_lowercase();
                    GUARD_TOKENS.iter().any(|g| t.contains(g))
                });
                if !(has_safety && has_guard) {
                    let mut missing = Vec::new();
                    if !has_safety {
                        missing.push("a SAFETY: comment restating the preconditions");
                    }
                    if !has_guard {
                        missing.push("evidence of CPU feature detection");
                    }
                    out.push(Finding::new(
                        "unsafe-boundary",
                        "unsafe-call-unguarded",
                        &file.rel,
                        i + 1,
                        name,
                        format!("call to unsafe fn `{name}` is missing {}", missing.join(" and ")),
                    ));
                }
            }
        }
    }
    out
}

/// Does `code` call `name` (identifier immediately followed by `(`),
/// excluding the `fn name(` declaration itself?
fn is_call_line(code: &str, name: &str) -> bool {
    let c = code.as_bytes();
    let w = name.as_bytes();
    if w.is_empty() || c.len() < w.len() + 1 {
        return false;
    }
    for i in 0..=c.len() - w.len() - 1 {
        if &c[i..i + w.len()] != w
            || (i > 0 && is_ident_byte(c[i - 1]))
            || c[i + w.len()] != b'('
        {
            continue;
        }
        let head = code[..i].trim_end();
        let is_decl = head.ends_with("fn")
            && (head.len() == 2 || !is_ident_byte(head.as_bytes()[head.len() - 3]));
        if !is_decl {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    const GEN_MACRO_WITH_DOC: &str = concat!(
        "macro_rules! gen_row {\n",
        "    ($name:ident, $regs:expr) => {\n",
        "        /// # Safety\n",
        "        /// Caller must verify AVX2 via is_x86_feature_detected!.\n",
        "        pub unsafe fn $name(lu: &[i32]) -> bool { lu.is_empty() }\n",
        "    };\n",
        "}\n",
        "gen_row!(row_w8, 1);\n",
    );

    fn findings(sources: &[(&str, &str)]) -> Vec<(String, &'static str, usize, String)> {
        let model = CrateModel::from_sources(sources);
        run(&model).into_iter().map(|f| (f.file, f.rule, f.line, f.symbol)).collect()
    }

    #[test]
    fn macro_template_without_safety_doc_is_flagged() {
        let bad = concat!(
            "macro_rules! gen_row {\n",
            "    ($name:ident, $regs:expr) => {\n",
            "        pub unsafe fn $name(lu: &[i32]) -> bool { lu.is_empty() }\n",
            "    };\n",
            "}\n",
            "gen_row!(row_w8, 1);\n",
        );
        let got = findings(&[("simd/avx2.rs", bad)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!((got[0].1, got[0].2, got[0].3.as_str()), ("unsafe-no-safety-doc", 3, "gen_row"));

        assert!(findings(&[("simd/avx2.rs", GEN_MACRO_WITH_DOC)]).is_empty());
    }

    #[test]
    fn plain_unsafe_fn_without_safety_doc_is_flagged() {
        let bad = "pub unsafe fn danger(p: *const i32) -> i32 { *p }\n";
        let got = findings(&[("simd/avx2.rs", bad)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].1, "unsafe-no-safety-doc");
        assert_eq!(got[0].3, "danger");

        let good = "/// # Safety\n/// `p` must be valid for reads.\npub unsafe fn danger(p: *const i32) -> i32 { *p }\n";
        assert!(findings(&[("simd/avx2.rs", good)]).is_empty());
    }

    #[test]
    fn unsafe_fns_outside_simd_are_out_of_scope() {
        let text = "pub unsafe fn raw_park(p: *const i32) -> i32 { *p }\n";
        assert!(findings(&[("util/par.rs", text)]).is_empty());
    }

    #[test]
    fn guarded_call_passes_and_unguarded_calls_fail() {
        let guarded = concat!(
            "pub fn dispatch(lu: &[i32]) -> bool {\n",
            "    // SAFETY: Backend::Avx2 is only constructed after detection.\n",
            "    unsafe { avx2::row_w8(lu) }\n",
            "}\n",
        );
        assert!(
            findings(&[("simd/avx2.rs", GEN_MACRO_WITH_DOC), ("simd/mod.rs", guarded)]).is_empty()
        );

        let no_safety = concat!(
            "pub fn dispatch(lu: &[i32]) -> bool {\n",
            "    unsafe { avx2::row_w8(lu) }\n",
            "}\n",
        );
        let got = findings(&[("simd/avx2.rs", GEN_MACRO_WITH_DOC), ("simd/mod.rs", no_safety)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!((got[0].0.as_str(), got[0].1, got[0].2), ("simd/mod.rs", "unsafe-call-unguarded", 2));

        let no_guard = concat!(
            "pub fn dispatch(lu: &[i32]) -> bool {\n",
            "    // SAFETY: caller promises the slices are padded.\n",
            "    unsafe { avx2::row_w8(lu) }\n",
            "}\n",
        );
        let got = findings(&[("simd/avx2.rs", GEN_MACRO_WITH_DOC), ("simd/mod.rs", no_guard)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].3 == "row_w8");
    }

    #[test]
    fn aliased_calls_are_checked_crate_wide() {
        let reexport = "pub use avx2::row_w8 as veclabel_row_avx2;\n";
        let caller = concat!(
            "pub fn fuse(lu: &[i32]) -> bool {\n",
            "    unsafe { crate::simd::veclabel_row_avx2(lu) }\n",
            "}\n",
        );
        let got = findings(&[
            ("simd/avx2.rs", GEN_MACRO_WITH_DOC),
            ("simd/mod.rs", reexport),
            ("labelprop/mod.rs", caller),
        ]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].0, "labelprop/mod.rs");
        assert_eq!(got[0].3, "veclabel_row_avx2");
    }

    #[test]
    fn test_code_and_declarations_are_not_call_sites() {
        let with_test = concat!(
            "macro_rules! gen_row {\n",
            "    ($name:ident, $regs:expr) => {\n",
            "        /// # Safety\n",
            "        /// Caller must verify AVX2 support first.\n",
            "        pub unsafe fn $name(lu: &[i32]) -> bool { lu.is_empty() }\n",
            "    };\n",
            "}\n",
            "gen_row!(row_w8, 1);\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() {\n",
            "        let _ = unsafe { super::row_w8(&[]) };\n",
            "    }\n",
            "}\n",
        );
        assert!(findings(&[("simd/avx2.rs", with_test)]).is_empty());
        assert!(!is_call_line("pub unsafe fn row_w8(lu: &[i32]) -> bool {", "row_w8"));
        assert!(is_call_line("let x = row_w8(lu);", "row_w8"));
    }
}
