//! The `xtask analyze` passes. Each pass takes the parsed
//! [`CrateModel`](crate::graph::CrateModel) and returns structured
//! [`Finding`]s; `run_all` runs all three and sorts the result into a
//! stable file/line/rule order.
//!
//! * [`determinism`] — nondeterminism sources (`HashMap` iteration,
//!   wall-clock reads, parallel float reductions) on paths reachable
//!   from kernel/algorithm entry points, unless justified by a
//!   `DETERMINISM:` comment.
//! * [`unsafe_boundary`] — every `unsafe fn` in `simd/` needs a
//!   `# Safety` contract and feature-detection-guarded call sites.
//! * [`knob_parity`] — every `RunOptions` field must be threaded through
//!   `from_json`, the CLI builder, and the coordinator banner.

pub(crate) mod determinism;
pub(crate) mod knob_parity;
pub(crate) mod unsafe_boundary;

use crate::findings::Finding;
use crate::graph::CrateModel;
use crate::parser::{FnItem, SourceFile};

/// Run all three analyze passes and sort the findings.
pub(crate) fn run_all(model: &CrateModel) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(determinism::run(model));
    out.extend(unsafe_boundary::run(model));
    out.extend(knob_parity::run(model));
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.symbol.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.symbol.as_str()))
    });
    out
}

/// The innermost parsed function whose body spans 0-based line `i`.
pub(crate) fn enclosing_fn(file: &SourceFile, i: usize) -> Option<&FnItem> {
    file.fns
        .iter()
        .filter(|f| f.body.is_some_and(|(lo, hi)| lo <= i && i <= hi))
        .min_by_key(|f| f.body.map_or(usize::MAX, |(lo, hi)| hi - lo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::findings::Waivers;
    use std::path::Path;

    #[test]
    fn enclosing_fn_picks_the_innermost_body() {
        let m = CrateModel::from_sources(&[(
            "algo/x.rs",
            "fn outer() {\n    fn inner() {\n        work();\n    }\n    inner();\n}\n",
        )]);
        let f = &m.files[0];
        assert_eq!(enclosing_fn(f, 2).unwrap().name, "inner");
        assert_eq!(enclosing_fn(f, 4).unwrap().name, "outer");
        assert!(enclosing_fn(f, 6).is_none());
    }

    /// The acceptance gate: `cargo xtask analyze` must run clean on the
    /// real crate — every finding either fixed at the source or waived
    /// in the checked-in waiver file.
    #[test]
    fn analyze_runs_clean_on_the_crate() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
        let (model, errors) = CrateModel::load_tree(&root).unwrap();
        assert!(errors.is_empty(), "unreadable files: {errors:?}");
        let mut findings = run_all(&model);
        let waivers =
            Waivers::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("analyze.waivers")).unwrap();
        waivers.apply(&mut findings);
        let unwaived: Vec<String> =
            findings.iter().filter(|f| !f.waived).map(|f| f.to_string()).collect();
        assert!(unwaived.is_empty(), "unwaived findings:\n{}", unwaived.join("\n"));
    }
}
