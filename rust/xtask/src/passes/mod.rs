//! The `xtask analyze` passes. Each pass takes the parsed
//! [`CrateModel`](crate::graph::CrateModel) and returns structured
//! [`Finding`]s; `run_all` runs all of them and sorts the result into a
//! stable file/line/rule order.
//!
//! * [`determinism`] — nondeterminism sources (`HashMap` iteration,
//!   wall-clock reads, parallel float reductions) on paths reachable
//!   from kernel/algorithm entry points, unless justified by a
//!   `DETERMINISM:` comment.
//! * [`unsafe_boundary`] — every `unsafe fn` in `simd/` needs a
//!   `# Safety` contract and feature-detection-guarded call sites.
//! * [`knob_parity`] — every `RunOptions` field must be threaded through
//!   `from_json`, the CLI builder, and the coordinator banner.
//! * [`panic_path`] — no `unwrap`/`expect`/`panic!`/unchecked indexing
//!   reachable from the serve request loop or `ImSession::query`,
//!   unless justified by a `PANIC-OK:` comment.
//! * [`lock_discipline`] — the facade `.lock()` acquisition graph must
//!   match the declared total order in `xtask/lock.order`.
//! * [`alloc_accountability`] — heap allocation on budget-admitted
//!   paths needs an `ACCOUNTED:` region or annotation.

pub(crate) mod alloc_accountability;
pub(crate) mod determinism;
pub(crate) mod knob_parity;
pub(crate) mod lock_discipline;
pub(crate) mod panic_path;
pub(crate) mod unsafe_boundary;

use crate::findings::Finding;
use crate::graph::CrateModel;
use crate::parser::{FnItem, SourceFile};
pub(crate) use lock_discipline::LockOrder;

/// Run every analyze pass and sort the findings.
pub(crate) fn run_all(model: &CrateModel, lock_order: &LockOrder) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(determinism::run(model));
    out.extend(unsafe_boundary::run(model));
    out.extend(knob_parity::run(model));
    out.extend(panic_path::run(model));
    out.extend(lock_discipline::run(model, lock_order));
    out.extend(alloc_accountability::run(model));
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.symbol.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.symbol.as_str()))
    });
    out
}

/// The innermost parsed function whose body spans 0-based line `i`.
pub(crate) fn enclosing_fn(file: &SourceFile, i: usize) -> Option<&FnItem> {
    file.fns
        .iter()
        .filter(|f| f.body.is_some_and(|(lo, hi)| lo <= i && i <= hi))
        .min_by_key(|f| f.body.map_or(usize::MAX, |(lo, hi)| hi - lo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::findings::Waivers;
    use std::path::Path;

    #[test]
    fn enclosing_fn_picks_the_innermost_body() {
        let m = CrateModel::from_sources(&[(
            "algo/x.rs",
            "fn outer() {\n    fn inner() {\n        work();\n    }\n    inner();\n}\n",
        )]);
        let f = &m.files[0];
        assert_eq!(enclosing_fn(f, 2).unwrap().name, "inner");
        assert_eq!(enclosing_fn(f, 4).unwrap().name, "outer");
        assert!(enclosing_fn(f, 6).is_none());
    }

    /// The real crate sources as owned `(rel, text)` pairs, so the
    /// acceptance self-tests can mutate them and re-analyze.
    fn real_sources() -> Vec<(String, String)> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
        let mut rels = Vec::new();
        crate::lint::collect_rs_files(&root, &root, &mut rels).unwrap();
        rels.sort();
        rels.into_iter()
            .map(|rel| {
                let text = std::fs::read_to_string(root.join(&rel)).unwrap();
                (rel, text)
            })
            .collect()
    }

    fn model_of(sources: &[(String, String)]) -> CrateModel {
        let refs: Vec<(&str, &str)> =
            sources.iter().map(|(rel, text)| (rel.as_str(), text.as_str())).collect();
        CrateModel::from_sources(&refs)
    }

    fn real_lock_order() -> LockOrder {
        LockOrder::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("lock.order")).unwrap()
    }

    /// The acceptance gate: `cargo xtask analyze` must run clean on the
    /// real crate — every finding either fixed at the source or waived
    /// in the checked-in waiver file, and no waiver or lock.order entry
    /// allowed to go stale.
    #[test]
    fn analyze_runs_clean_on_the_crate() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
        let (model, errors) = CrateModel::load_tree(&root).unwrap();
        assert!(errors.is_empty(), "unreadable files: {errors:?}");
        let mut findings = run_all(&model, &real_lock_order());
        let waivers =
            Waivers::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("analyze.waivers")).unwrap();
        waivers.apply(&mut findings);
        findings.extend(waivers.stale_findings(&model));
        let unwaived: Vec<String> =
            findings.iter().filter(|f| !f.waived).map(|f| f.to_string()).collect();
        assert!(unwaived.is_empty(), "unwaived findings:\n{}", unwaived.join("\n"));
    }

    /// The lock manifest must match the *derived* lock roster exactly:
    /// run against an empty manifest, every real site surfaces as
    /// `lock-undeclared`, and that roster is non-trivial. Against the
    /// real manifest there is nothing undeclared and nothing stale — so
    /// renaming any lock site (or editing lock.order by hand) breaks
    /// one direction of this equality.
    #[test]
    fn real_lock_roster_matches_the_manifest_exactly() {
        let model = model_of(&real_sources());
        let empty = LockOrder::parse("").unwrap();
        let derived: std::collections::BTreeSet<String> = lock_discipline::run(&model, &empty)
            .into_iter()
            .filter(|f| f.rule == "lock-undeclared")
            .map(|f| f.symbol)
            .collect();
        assert!(
            derived.iter().any(|n| n.starts_with("serve/pool."))
                && derived.iter().any(|n| n.starts_with("runtime/")),
            "expected facade locks in both serve/ and runtime/, derived {derived:?}"
        );
        let real: Vec<String> = lock_discipline::run(&model, &real_lock_order())
            .iter()
            .map(|f| f.to_string())
            .collect();
        assert!(real.is_empty(), "lock pass not clean on the real tree:\n{}", real.join("\n"));
    }

    /// Renaming a real lock site is caught: the derived name changes,
    /// so the site becomes `lock-undeclared` and its manifest entry
    /// goes `lock-stale-order`.
    #[test]
    fn renaming_a_real_lock_site_is_caught() {
        let mut sources = real_sources();
        let pool = sources.iter_mut().find(|(rel, _)| rel == "serve/pool.rs").unwrap();
        assert!(pool.1.contains("session.lock()"), "expected the session lock site");
        pool.1 = pool.1.replace("session.lock()", "renamed_session.lock()");
        let model = model_of(&sources);
        let rules: Vec<&'static str> =
            lock_discipline::run(&model, &real_lock_order()).iter().map(|f| f.rule).collect();
        assert!(rules.contains(&"lock-undeclared"), "{rules:?}");
        assert!(rules.contains(&"lock-stale-order"), "{rules:?}");
    }

    /// Deleting any real `ACCOUNTED:` annotation re-opens the sites it
    /// cleared on the budget-admitted surfaces.
    #[test]
    fn deleting_real_accounted_annotations_is_caught() {
        let mut sources = real_sources();
        let mut stripped = false;
        for (rel, text) in sources.iter_mut() {
            if (rel == "serve/pool.rs" || rel.starts_with("rr/")) && text.contains("ACCOUNTED") {
                *text = text.replace("ACCOUNTED", "REDACTED");
                stripped = true;
            }
        }
        assert!(stripped, "the budget surfaces must carry ACCOUNTED annotations");
        let got = alloc_accountability::run(&model_of(&sources));
        assert!(!got.is_empty(), "stripping every ACCOUNTED annotation must reopen sites");
    }

    /// Deleting any real `PANIC-OK:` justification re-opens the panic
    /// sites it cleared on the serve-reachable surface.
    #[test]
    fn deleting_real_panic_ok_annotations_is_caught() {
        let mut sources = real_sources();
        let mut stripped = false;
        for (_, text) in sources.iter_mut() {
            if text.contains("PANIC-OK") {
                *text = text.replace("PANIC-OK", "REDACTED");
                stripped = true;
            }
        }
        assert!(stripped, "the serve surface must carry PANIC-OK justifications");
        let got = panic_path::run(&model_of(&sources));
        assert!(!got.is_empty(), "stripping every PANIC-OK justification must reopen sites");
    }
}
