//! A lightweight Rust item/body parser on top of the shared lexer — no
//! dependencies, no syn. It recovers exactly the structure the analyze
//! passes need and nothing more:
//!
//! * functions — name, `pub`/`unsafe` flags, body line span (by brace
//!   counting over lexed code text), and the calls inside the body;
//! * structs with named fields (name + declaration line per field);
//! * `macro_rules!` definitions, flagging the ones whose bodies expand to
//!   `unsafe fn` items, plus their invocations (`mac!(name, ...)` is
//!   treated as declaring the function `name` — the `simd/avx2.rs`
//!   kernel-generator pattern);
//! * `use ... as ...` aliases and `mod x;` declarations (module graph).
//!
//! The parser is deliberately an over-approximation: it may attribute a
//! nested function's calls to its enclosing item too, and it never
//! resolves types. The passes are designed so that over-approximation
//! can only widen the analyzed scope, never hide a finding.

use crate::lexer::{self, classify, test_mask, word_position, Line};

/// One parsed source file: raw text, lexed lines, test mask, and items.
pub(crate) struct SourceFile {
    /// Path relative to the scan root, `/`-separated.
    pub rel: String,
    /// Raw line text (same indexing as `lines`).
    pub raw: Vec<String>,
    /// Lexed lines (code/comment split).
    pub lines: Vec<Line>,
    /// Per-line `#[cfg(test)]` membership.
    pub mask: Vec<bool>,
    pub fns: Vec<FnItem>,
    /// `impl [Trait for] Type` blocks (self-type name + body span).
    pub impls: Vec<ImplBlock>,
    pub structs: Vec<StructItem>,
    pub macros: Vec<MacroDef>,
    /// Functions declared by invoking an unsafe-fn-generating macro.
    pub generated: Vec<GeneratedFn>,
    /// `target as alias` ident pairs (from `use` lists and anywhere else;
    /// consumers look up by target name, so cast noise is inert).
    pub aliases: Vec<(String, String)>,
    /// `mod x;` out-of-line module declarations.
    pub mods: Vec<String>,
}

#[derive(Debug)]
pub(crate) struct FnItem {
    pub name: String,
    /// 0-based declaration line.
    pub line: usize,
    /// 0-based inclusive line span from the declaration through the
    /// body's closing brace; `None` for bodyless trait signatures.
    pub body: Option<(usize, usize)>,
    pub is_pub: bool,
    pub is_unsafe: bool,
    pub in_test: bool,
    /// Self type of the innermost enclosing `impl` block, when any. For
    /// `impl Trait for Type` the owner is `Type` (the last path segment,
    /// generics stripped) — the name a `Type::method` call site uses.
    pub owner: Option<String>,
    pub calls: Vec<CallRef>,
}

#[derive(Debug)]
pub(crate) struct ImplBlock {
    /// Last path segment of the self type, generics stripped (`Foo` in
    /// `impl<T> fmt::Display for Foo<T>`).
    pub self_type: String,
    /// 0-based inclusive line span (declaration through closing brace).
    pub body: (usize, usize),
}

#[derive(Debug)]
pub(crate) struct CallRef {
    pub name: String,
    /// Last path segment before the call (`avx2` in `avx2::row_w8(...)`).
    pub qualifier: Option<String>,
    /// True for `.name(...)` method-call syntax.
    pub is_method: bool,
    /// 0-based line — diagnostic context, read by the self-tests.
    #[allow(dead_code)]
    pub line: usize,
}

#[derive(Debug)]
pub(crate) struct StructItem {
    pub name: String,
    /// 0-based declaration line.
    pub line: usize,
    /// Named fields: `(name, 0-based declaration line)`.
    pub fields: Vec<(String, usize)>,
}

#[derive(Debug)]
pub(crate) struct MacroDef {
    pub name: String,
    /// 0-based inclusive body span.
    pub body: (usize, usize),
    /// Lines inside the body declaring `unsafe fn` templates.
    pub unsafe_fn_lines: Vec<usize>,
}

#[derive(Debug)]
pub(crate) struct GeneratedFn {
    /// The function name the invocation generates.
    pub name: String,
    pub macro_name: String,
    /// 0-based invocation line — diagnostic context, read by the
    /// self-tests.
    #[allow(dead_code)]
    pub line: usize,
    /// The `unsafe fn` template line inside the macro body (for doc
    /// checks), when the macro generates unsafe fns.
    pub template_line: usize,
}

const KEYWORDS: [&str; 18] = [
    "if", "else", "while", "match", "for", "loop", "return", "fn", "in", "as", "move", "let",
    "unsafe", "where", "impl", "use", "pub", "ref",
];

/// Parse one file into the item model.
pub(crate) fn parse(rel: &str, text: &str) -> SourceFile {
    let lines = classify(text);
    let mask = test_mask(&lines);
    let mut raw: Vec<String> = text.lines().map(|l| l.to_string()).collect();
    raw.resize(lines.len().max(raw.len()), String::new());

    let mut file = SourceFile {
        rel: rel.to_string(),
        raw,
        lines,
        mask,
        fns: Vec::new(),
        impls: Vec::new(),
        structs: Vec::new(),
        macros: Vec::new(),
        generated: Vec::new(),
        aliases: Vec::new(),
        mods: Vec::new(),
    };

    parse_macros(&mut file);
    parse_impls(&mut file);
    parse_fns(&mut file);
    parse_structs(&mut file);
    parse_generated(&mut file);
    parse_aliases_and_mods(&mut file);
    file
}

fn ident_at(code: &str, mut i: usize) -> Option<(String, usize)> {
    let b = code.as_bytes();
    while i < b.len() && (b[i] == b' ' || b[i] == b'\t') {
        i += 1;
    }
    let start = i;
    while i < b.len() && lexer::is_ident_byte(b[i]) {
        i += 1;
    }
    if i > start && !b[start].is_ascii_digit() {
        Some((code[start..i].to_string(), i))
    } else {
        None
    }
}

/// Scan character-wise from `(line, col)` to find the item's body span:
/// the first top-level `{` opens it, the matching `}` closes it; a `;`
/// before any `{` means a bodyless signature. Returns the inclusive line
/// span of the body (starting at `line`), or `None`.
fn body_span(lines: &[Line], line: usize, col: usize) -> Option<(usize, usize)> {
    let mut depth = 0i64;
    let mut opened = false;
    let mut j = line;
    let mut start_col = col;
    while j < lines.len() {
        let code = lines[j].code.as_bytes();
        for &ch in code.iter().skip(start_col) {
            match ch {
                b'{' => {
                    depth += 1;
                    opened = true;
                }
                b'}' => depth -= 1,
                b';' if !opened && depth == 0 => return None,
                _ => {}
            }
            if opened && depth <= 0 {
                return Some((line, j));
            }
        }
        start_col = 0;
        j += 1;
    }
    // Unterminated (truncated fixture): treat the rest of the file as the
    // body rather than dropping the item.
    opened.then(|| (line, lines.len().saturating_sub(1)))
}

/// Skip a balanced `<...>` generic-argument list starting at `i` (which
/// must point at `<`). Every `>` closes one level, so `>>` closes two —
/// correct for type position, where shift operators cannot appear.
fn skip_generics(b: &[u8], mut i: usize) -> usize {
    let mut depth = 0i64;
    while i < b.len() {
        match b[i] {
            b'<' => depth += 1,
            b'>' => {
                depth -= 1;
                if depth <= 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    i
}

/// Parse a type path at `i`: `seg(::seg)*`, each segment optionally
/// followed by generics. Returns the last segment name and the index
/// just past the path. Leading `&`/`dyn` noise is skipped.
fn type_path(code: &str, mut i: usize) -> Option<(String, usize)> {
    let b = code.as_bytes();
    loop {
        while i < b.len() && (b[i] == b' ' || b[i] == b'\t' || b[i] == b'&') {
            i += 1;
        }
        match ident_at(code, i) {
            Some((w, end)) if w == "dyn" || w == "mut" => i = end,
            _ => break,
        }
    }
    let mut last = None;
    loop {
        let (seg, mut end) = ident_at(code, i)?;
        last = Some(seg);
        if end < b.len() && b[end] == b'<' {
            end = skip_generics(b, end);
        }
        if code[end..].starts_with("::") {
            i = end + 2;
        } else {
            return last.map(|s| (s, end));
        }
    }
}

/// Recognize `impl [Trait for] Type` blocks. Only lines whose code
/// *starts* with `impl` (after an optional `unsafe`) are considered, so
/// `impl Trait` in argument or return position never creates a block.
fn parse_impls(file: &mut SourceFile) {
    for i in 0..file.lines.len() {
        let code = file.lines[i].code.clone();
        let trimmed = code.trim_start();
        let rest = trimmed.strip_prefix("unsafe ").map(str::trim_start).unwrap_or(trimmed);
        if !(rest.starts_with("impl") && !lexer::is_ident_byte(*rest.as_bytes().get(4).unwrap_or(&b'{'))) {
            continue;
        }
        let base = code.len() - rest.len();
        let mut pos = base + 4;
        let b = code.as_bytes();
        // Generic parameters on the impl itself: `impl<T: Bound> ...`.
        let mut j = pos;
        while j < b.len() && (b[j] == b' ' || b[j] == b'\t') {
            j += 1;
        }
        if j < b.len() && b[j] == b'<' {
            pos = skip_generics(b, j);
        }
        let Some((first, end)) = type_path(&code, pos) else { continue };
        let after = code[end..].trim_start();
        let self_type = if after.starts_with("for")
            && !lexer::is_ident_byte(*after.as_bytes().get(3).unwrap_or(&b' '))
        {
            let for_pos = end + (code[end..].len() - after.len()) + 3;
            match type_path(&code, for_pos) {
                Some((t, _)) => t,
                None => continue,
            }
        } else {
            first
        };
        let Some(body) = body_span(&file.lines, i, end) else { continue };
        file.impls.push(ImplBlock { self_type, body });
    }
}

/// Innermost impl block whose span contains line `i`.
fn owner_at(impls: &[ImplBlock], i: usize) -> Option<String> {
    impls
        .iter()
        .filter(|b| b.body.0 <= i && i <= b.body.1)
        .max_by_key(|b| b.body.0)
        .map(|b| b.self_type.clone())
}

fn parse_fns(file: &mut SourceFile) {
    let n = file.lines.len();
    for i in 0..n {
        let code = file.lines[i].code.clone();
        let Some(pos) = word_position(&code, "fn") else { continue };
        let Some((name, name_end)) = ident_at(&code, pos + 2) else { continue };
        // `$name` macro templates are handled by parse_macros/generated.
        let before = &code[..pos];
        let is_unsafe = lexer::has_word(before, "unsafe");
        let is_pub = lexer::has_word(before, "pub");
        let body = body_span(&file.lines, i, name_end);
        let mut calls = Vec::new();
        if let Some((lo, hi)) = body {
            for j in lo..=hi.min(n - 1) {
                extract_calls(&file.lines[j].code, j, &mut calls);
            }
        }
        file.fns.push(FnItem {
            name,
            line: i,
            body,
            is_pub,
            is_unsafe,
            in_test: file.mask[i],
            owner: owner_at(&file.impls, i),
            calls,
        });
    }
}

fn extract_calls(code: &str, line: usize, out: &mut Vec<CallRef>) {
    let b = code.as_bytes();
    let mut i = 0usize;
    while i < b.len() {
        if !(lexer::is_ident_byte(b[i]) && !b[i].is_ascii_digit())
            || (i > 0 && lexer::is_ident_byte(b[i - 1]))
        {
            i += 1;
            continue;
        }
        let start = i;
        while i < b.len() && lexer::is_ident_byte(b[i]) {
            i += 1;
        }
        let name = &code[start..i];
        if i >= b.len() || b[i] != b'(' || KEYWORDS.contains(&name) {
            continue;
        }
        // `fn name(` is a declaration, not a call.
        let head = code[..start].trim_end();
        if head.ends_with("fn")
            && (head.len() == 2 || !lexer::is_ident_byte(head.as_bytes()[head.len() - 3]))
        {
            continue;
        }
        let mut qualifier = None;
        let mut is_method = false;
        if start >= 2 && &b[start - 2..start] == b"::" {
            let q_end = start - 2;
            let mut q_start = q_end;
            while q_start > 0 && lexer::is_ident_byte(b[q_start - 1]) {
                q_start -= 1;
            }
            if q_start < q_end {
                qualifier = Some(code[q_start..q_end].to_string());
            }
        } else if start >= 1 && b[start - 1] == b'.' {
            is_method = true;
        }
        out.push(CallRef { name: name.to_string(), qualifier, is_method, line });
    }
}

fn parse_structs(file: &mut SourceFile) {
    let n = file.lines.len();
    for i in 0..n {
        let code = &file.lines[i].code;
        let Some(pos) = word_position(code, "struct") else { continue };
        let Some((name, name_end)) = ident_at(code, pos + 6) else { continue };
        let Some((lo, hi)) = body_span(&file.lines, i, name_end) else {
            continue; // unit / tuple struct: no named fields
        };
        // Tuple structs `struct X(u32);` never reach here (no `{`), but
        // `struct X(...)` followed by a where-clause brace would; the
        // field scan below simply finds nothing in that case.
        let mut fields = Vec::new();
        let mut depth = 0i64;
        for j in lo..=hi.min(n - 1) {
            let line_code = &file.lines[j].code;
            let depth_at_start = depth;
            for ch in line_code.bytes() {
                match ch {
                    b'{' => depth += 1,
                    b'}' => depth -= 1,
                    _ => {}
                }
            }
            if depth_at_start != 1 && !(j == lo && depth == 1) {
                // Fields live at depth 1; also allow `struct X { f: T }`
                // one-liners (depth becomes 1 on the decl line itself).
                if !(j == lo && line_code.contains('{')) {
                    continue;
                }
            }
            let mut rest = line_code.as_str();
            if j == lo {
                // Start after the opening brace on the decl line.
                match rest.find('{') {
                    Some(p) => rest = &rest[p + 1..],
                    None => continue,
                }
            }
            let trimmed = rest.trim_start();
            if trimmed.is_empty() || trimmed.starts_with('#') {
                continue;
            }
            let mut t = trimmed;
            if let Some(p) = word_position(t, "pub") {
                if p == 0 {
                    t = &t[3..];
                    let tt = t.trim_start();
                    if tt.starts_with('(') {
                        match tt.find(')') {
                            Some(p2) => t = &tt[p2 + 1..],
                            None => continue,
                        }
                    } else {
                        t = tt;
                    }
                }
            }
            if let Some((fname, end)) = ident_at(t, 0) {
                let after = t[end..].trim_start();
                if after.starts_with(':') && !after.starts_with("::") {
                    fields.push((fname, j));
                }
            }
        }
        file.structs.push(StructItem { name, line: i, fields });
    }
}

fn parse_macros(file: &mut SourceFile) {
    let n = file.lines.len();
    for i in 0..n {
        let code = &file.lines[i].code;
        let Some(pos) = word_position(code, "macro_rules") else { continue };
        let after = code[pos + "macro_rules".len()..].trim_start();
        let Some(rest) = after.strip_prefix('!') else { continue };
        let Some((name, _)) = ident_at(rest, 0) else { continue };
        let Some((lo, hi)) = body_span(&file.lines, i, pos) else { continue };
        let mut unsafe_fn_lines = Vec::new();
        for j in lo..=hi.min(n - 1) {
            let c = &file.lines[j].code;
            if lexer::has_word(c, "unsafe") && lexer::has_word(c, "fn") {
                unsafe_fn_lines.push(j);
            }
        }
        file.macros.push(MacroDef { name, body: (lo, hi), unsafe_fn_lines });
    }
}

fn parse_generated(file: &mut SourceFile) {
    let mut generated = Vec::new();
    for mac in &file.macros {
        let Some(&template_line) = mac.unsafe_fn_lines.first() else { continue };
        for (j, line) in file.lines.iter().enumerate() {
            if j >= mac.body.0 && j <= mac.body.1 {
                continue; // the definition itself
            }
            let code = &line.code;
            let Some(pos) = word_position(code, &mac.name) else { continue };
            let after = &code[pos + mac.name.len()..];
            let Some(args) = after.strip_prefix('!') else { continue };
            let args = args.trim_start();
            let Some(args) = args.strip_prefix('(').or_else(|| args.strip_prefix('{')) else {
                continue;
            };
            if let Some((gname, _)) = ident_at(args, 0) {
                generated.push(GeneratedFn {
                    name: gname,
                    macro_name: mac.name.clone(),
                    line: j,
                    template_line,
                });
            }
        }
    }
    file.generated = generated;
}

fn parse_aliases_and_mods(file: &mut SourceFile) {
    for (j, line) in file.lines.iter().enumerate() {
        let code = &line.code;
        // `target as alias` pairs.
        let b = code.as_bytes();
        let mut search_from = 0usize;
        while let Some(rel_pos) = word_position(&code[search_from..], "as") {
            let pos = search_from + rel_pos;
            let before = code[..pos].trim_end();
            let target = before
                .rfind(|c: char| !lexer::is_ident_char(c))
                .map(|p| &before[p + 1..])
                .unwrap_or(before);
            if let Some((alias, _)) = ident_at(code, pos + 2) {
                if !target.is_empty()
                    && !target.as_bytes()[0].is_ascii_digit()
                    && !alias.is_empty()
                {
                    file.aliases.push((target.to_string(), alias));
                }
            }
            search_from = pos + 2;
            if search_from >= b.len() {
                break;
            }
        }
        // `mod x;` declarations (out-of-line modules).
        if let Some(pos) = word_position(code, "mod") {
            if let Some((name, end)) = ident_at(code, pos + 3) {
                if code[end..].trim_start().starts_with(';') {
                    let _ = j;
                    file.mods.push(name);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_fns_with_flags_bodies_and_calls() {
        let text = concat!(
            "pub fn outer(x: u32) -> u32 {\n",
            "    helper(x) + other::helper2(x)\n",
            "}\n",
            "unsafe fn danger(p: *mut u8) {}\n",
            "fn bodyless_type(f: fn(u32) -> u32) -> u32 { f(1) }\n",
        );
        let f = parse("algo/x.rs", text);
        let names: Vec<&str> = f.fns.iter().map(|i| i.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "danger", "bodyless_type"]);
        let outer = &f.fns[0];
        assert!(outer.is_pub && !outer.is_unsafe);
        assert_eq!(outer.body, Some((0, 2)));
        let calls: Vec<(&str, Option<&str>)> = outer
            .calls
            .iter()
            .map(|c| (c.name.as_str(), c.qualifier.as_deref()))
            .collect();
        assert!(calls.contains(&("helper", None)), "{calls:?}");
        assert!(calls.contains(&("helper2", Some("other"))), "{calls:?}");
        assert!(f.fns[1].is_unsafe);
    }

    #[test]
    fn trait_signatures_have_no_body() {
        let text = "trait T {\n    fn labels(&self) -> u32;\n    fn with_default(&self) -> u32 { 1 }\n}\n";
        let f = parse("algo/x.rs", text);
        let labels = f.fns.iter().find(|i| i.name == "labels").unwrap();
        assert_eq!(labels.body, None);
        let wd = f.fns.iter().find(|i| i.name == "with_default").unwrap();
        assert_eq!(wd.body, Some((2, 2)));
    }

    #[test]
    fn method_calls_are_flagged() {
        let text = "fn f(e: E) { e.run(); plain(); }\n";
        let f = parse("algo/x.rs", text);
        let calls = &f.fns[0].calls;
        let run = calls.iter().find(|c| c.name == "run").unwrap();
        assert!(run.is_method);
        let plain = calls.iter().find(|c| c.name == "plain").unwrap();
        assert!(!plain.is_method);
    }

    #[test]
    fn parses_struct_fields_with_lines() {
        let text = concat!(
            "#[derive(Debug)]\n",
            "pub struct Opts {\n",
            "    /// docs\n",
            "    pub r_count: usize,\n",
            "    pub(crate) seed: u64,\n",
            "    threads: usize,\n",
            "    pub timeout: Option<Duration>,\n",
            "}\n",
        );
        let f = parse("api/options.rs", text);
        assert_eq!(f.structs.len(), 1);
        let s = &f.structs[0];
        assert_eq!(s.name, "Opts");
        let fields: Vec<&str> = s.fields.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(fields, vec!["r_count", "seed", "threads", "timeout"]);
        assert_eq!(s.fields[0].1, 3, "field line is the declaration line");
    }

    #[test]
    fn nested_braces_do_not_leak_fields() {
        // A nested type expression with braces must not promote inner
        // idents to fields of the outer struct.
        let text = concat!(
            "struct A {\n",
            "    cb: fn() -> u32,\n",
            "}\n",
            "struct B { x: u32 }\n",
        );
        let f = parse("x.rs", text);
        assert_eq!(f.structs.len(), 2);
        assert_eq!(f.structs[0].fields.len(), 1);
        assert_eq!(f.structs[1].fields, vec![("x".to_string(), 3)]);
    }

    #[test]
    fn unsafe_generating_macros_and_invocations_are_linked() {
        let text = concat!(
            "macro_rules! gen_kernel {\n",
            "    ($name:ident, $regs:expr) => {\n",
            "        /// # Safety\n",
            "        /// CPU must support AVX2.\n",
            "        pub unsafe fn $name(x: &[i32]) -> bool { x.is_empty() }\n",
            "    };\n",
            "}\n",
            "gen_kernel!(row_w8, 1);\n",
            "gen_kernel!(row_w16, 2);\n",
        );
        let f = parse("simd/avx2.rs", text);
        assert_eq!(f.macros.len(), 1);
        assert_eq!(f.macros[0].name, "gen_kernel");
        assert_eq!(f.macros[0].unsafe_fn_lines, vec![4]);
        let gen: Vec<(&str, usize)> =
            f.generated.iter().map(|g| (g.name.as_str(), g.line)).collect();
        assert_eq!(gen, vec![("row_w8", 7), ("row_w16", 8)]);
        assert_eq!(f.generated[0].template_line, 4);
    }

    #[test]
    fn aliases_and_mods_are_recorded() {
        let text = concat!(
            "pub use avx2::{masked_w8 as row_masked, row_w8 as row_plain};\n",
            "mod scalar;\n",
            "pub mod avx2;\n",
            "fn f(x: u64) -> usize { x as usize }\n",
        );
        let f = parse("simd/mod.rs", text);
        assert!(f.aliases.contains(&("masked_w8".to_string(), "row_masked".to_string())));
        assert!(f.aliases.contains(&("row_w8".to_string(), "row_plain".to_string())));
        assert_eq!(f.mods, vec!["scalar", "avx2"]);
    }

    #[test]
    fn impl_blocks_assign_owners() {
        let text = concat!(
            "pub struct Pool;\n",
            "impl Pool {\n",
            "    pub fn open(&self) { self.tick() }\n",
            "}\n",
            "impl<T: Clone> fmt::Display for Wrapper<T> {\n",
            "    fn fmt(&self) -> u32 { 0 }\n",
            "}\n",
            "unsafe impl Send for Pool {}\n",
            "pub fn free() {}\n",
            "fn takes(x: impl Iterator<Item = u32>) -> u32 { 0 }\n",
        );
        let f = parse("serve/pool.rs", text);
        let types: Vec<&str> = f.impls.iter().map(|b| b.self_type.as_str()).collect();
        assert_eq!(types, vec!["Pool", "Wrapper", "Pool"], "{types:?}");
        let owners: Vec<(&str, Option<&str>)> =
            f.fns.iter().map(|i| (i.name.as_str(), i.owner.as_deref())).collect();
        assert!(owners.contains(&("open", Some("Pool"))), "{owners:?}");
        assert!(owners.contains(&("fmt", Some("Wrapper"))), "{owners:?}");
        assert!(owners.contains(&("free", None)), "{owners:?}");
        assert!(owners.contains(&("takes", None)), "{owners:?}");
    }

    #[test]
    fn impl_in_argument_or_return_position_is_not_a_block() {
        let text = concat!(
            "fn mk() -> impl Iterator<Item = u32> {\n",
            "    (0..3).map(|x| x)\n",
            "}\n",
            "fn use_it(it: impl Iterator<Item = u32>) -> usize { it.count() }\n",
        );
        let f = parse("algo/x.rs", text);
        assert!(f.impls.is_empty(), "{:?}", f.impls);
        assert!(f.fns.iter().all(|i| i.owner.is_none()));
    }

    /// Hand-rolled property test (no deps): generate random nestings of
    /// fns, closures, and plain blocks from a seeded LCG, then check the
    /// recovered body spans are well-formed — each span starts at its
    /// declaration line, braces balance to zero across it, and every
    /// nested fn's span sits inside some enclosing span or after it,
    /// never straddling a boundary.
    #[test]
    fn proptest_body_spans_over_nested_items() {
        let mut state = 0x9e37_79b9_7f4a_7c15u64;
        let mut rng = move |bound: usize| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as usize) % bound.max(1)
        };
        for case in 0..200 {
            let mut text = String::new();
            let mut names: Vec<String> = Vec::new();
            let mut depth = 0usize;
            let mut emitted = 0usize;
            while emitted < 12 {
                match rng(4) {
                    0 => {
                        let name = format!("f{}_{}", case, emitted);
                        text.push_str(&format!("fn {name}(x: u32) -> u32 {{\n"));
                        names.push(name);
                        depth += 1;
                        emitted += 1;
                    }
                    1 if depth > 0 => {
                        // A closure with a braced body, on one line.
                        text.push_str("    let c = |y: u32| { y + 1 };\n");
                        emitted += 1;
                    }
                    2 if depth > 0 => {
                        text.push_str("    {\n        helper(x);\n    }\n");
                        emitted += 1;
                    }
                    _ if depth > 0 => {
                        text.push_str("}\n");
                        depth -= 1;
                    }
                    _ => {
                        text.push_str("// filler\n");
                    }
                }
            }
            while depth > 0 {
                text.push_str("}\n");
                depth -= 1;
            }
            let f = parse("algo/gen.rs", &text);
            let found: Vec<&str> = f.fns.iter().map(|i| i.name.as_str()).collect();
            for name in &names {
                assert!(found.contains(&name.as_str()), "case {case}: lost fn {name}\n{text}");
            }
            let mut spans: Vec<(usize, usize)> = Vec::new();
            for item in &f.fns {
                let (lo, hi) = item.body.expect("generated fns always have bodies");
                assert_eq!(lo, item.line, "case {case}: span must start at the decl");
                assert!(hi >= lo && hi < f.lines.len(), "case {case}: span out of range");
                let mut bal = 0i64;
                for line in &f.lines[lo..=hi] {
                    for ch in line.code.bytes() {
                        match ch {
                            b'{' => bal += 1,
                            b'}' => bal -= 1,
                            _ => {}
                        }
                    }
                }
                assert_eq!(bal, 0, "case {case}: unbalanced span {lo}..={hi}\n{text}");
                spans.push((lo, hi));
            }
            for &(lo, hi) in &spans {
                for &(lo2, hi2) in &spans {
                    let nested = lo2 > lo && lo2 <= hi;
                    assert!(
                        !nested || hi2 <= hi,
                        "case {case}: straddling spans ({lo},{hi}) vs ({lo2},{hi2})\n{text}"
                    );
                }
            }
        }
    }

    #[test]
    fn fn_decl_is_not_its_own_call() {
        let text = "pub fn session_options(args: &Args) -> u32 { helper(args) }\n";
        let f = parse("main.rs", text);
        let calls: Vec<&str> = f.fns[0].calls.iter().map(|c| c.name.as_str()).collect();
        assert!(!calls.contains(&"session_options"), "{calls:?}");
        assert!(calls.contains(&"helper"), "{calls:?}");
    }
}
