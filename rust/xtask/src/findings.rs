//! Structured findings shared by `xtask analyze` and `xtask lint`.
//!
//! Every pass emits [`Finding`]s — `file:line`, the pass and rule ids, an
//! optional symbol (function, field, or token the rule anchored on), and
//! a human message. Findings render as text for terminals and as JSON
//! (`--json`) for CI artifacts, and can be *waived* by a checked-in
//! waiver file:
//!
//! ```text
//! # analyze.waivers — one waiver per line:
//! #   <rule> <file> <symbol|*>        # trailing comments allowed
//! det-hash-iter graph/io.rs *
//! knob-missing-banner coordinator/mod.rs timeout
//! ```
//!
//! A waiver matches a finding when the rule and file are equal and the
//! symbol is equal or the waiver declares `*`. Waived findings still
//! appear in the JSON artifact (flagged `"waived": true`) but do not
//! fail the run.

use crate::lint::Violation;
use std::fmt;

/// One structured finding from a pass.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Which pass produced it (`lint`, `determinism`, `unsafe-boundary`,
    /// `knob-parity`).
    pub pass: &'static str,
    /// Stable rule id within the pass.
    pub rule: &'static str,
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// The symbol the rule anchored on (fn name, struct field, token);
    /// empty when the rule has no natural anchor.
    pub symbol: String,
    pub msg: String,
    /// Set by [`Waivers::apply`] when a waiver matches.
    pub waived: bool,
}

impl Finding {
    pub fn new(
        pass: &'static str,
        rule: &'static str,
        file: &str,
        line: usize,
        symbol: &str,
        msg: String,
    ) -> Self {
        Self {
            pass,
            rule,
            file: file.to_string(),
            line,
            symbol: symbol.to_string(),
            msg,
            waived: false,
        }
    }

    /// Adapt a lint [`Violation`] into the shared finding shape.
    pub fn from_lint(v: Violation) -> Self {
        Self {
            pass: "lint",
            rule: v.rule,
            file: v.file,
            line: v.line,
            symbol: String::new(),
            msg: v.msg,
            waived: false,
        }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}/{}] {}", self.file, self.line, self.pass, self.rule, self.msg)?;
        if self.waived {
            write!(f, " (waived)")?;
        }
        Ok(())
    }
}

/// Render findings as a JSON array (stable key order, no dependencies).
pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("[\n");
    for (i, f) in findings.iter().enumerate() {
        out.push_str("  {");
        out.push_str(&format!("\"pass\": \"{}\", ", json_escape(f.pass)));
        out.push_str(&format!("\"rule\": \"{}\", ", json_escape(f.rule)));
        out.push_str(&format!("\"file\": \"{}\", ", json_escape(&f.file)));
        out.push_str(&format!("\"line\": {}, ", f.line));
        out.push_str(&format!("\"symbol\": \"{}\", ", json_escape(&f.symbol)));
        out.push_str(&format!("\"msg\": \"{}\", ", json_escape(&f.msg)));
        out.push_str(&format!("\"waived\": {}", f.waived));
        out.push('}');
        if i + 1 < findings.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push(']');
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One parsed waiver line.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Waiver {
    rule: String,
    file: String,
    /// `*` matches any symbol.
    symbol: String,
}

/// The parsed waiver file.
#[derive(Debug, Default)]
pub struct Waivers {
    entries: Vec<Waiver>,
}

impl Waivers {
    /// Parse waiver text: one `<rule> <file> <symbol|*>` per line, blank
    /// lines and `#` comments (full-line or trailing) ignored. A
    /// malformed line is an error naming its line number — a silently
    /// dropped waiver would un-waive a finding and fail CI confusingly.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(pos) => &raw[..pos],
                None => raw,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 3 {
                return Err(format!(
                    "waiver line {}: expected '<rule> <file> <symbol|*>', got '{line}'",
                    lineno + 1
                ));
            }
            entries.push(Waiver {
                rule: parts[0].to_string(),
                file: parts[1].to_string(),
                symbol: parts[2].to_string(),
            });
        }
        Ok(Self { entries })
    }

    /// Load from a path; a missing file is an empty waiver set.
    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Self::parse(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Self::default()),
            Err(e) => Err(format!("read {}: {e}", path.display())),
        }
    }

    fn matches(&self, f: &Finding) -> bool {
        self.entries.iter().any(|w| {
            w.rule == f.rule && w.file == f.file && (w.symbol == "*" || w.symbol == f.symbol)
        })
    }

    /// Mark matching findings as waived; returns how many were waived.
    pub fn apply(&self, findings: &mut [Finding]) -> usize {
        let mut n = 0;
        for f in findings.iter_mut() {
            if self.matches(f) {
                f.waived = true;
                n += 1;
            }
        }
        n
    }

    /// Stale-waiver check: every entry must still name a real file in
    /// the analyzed tree and — unless the symbol is `*` — a symbol
    /// that still exists there (a fn, struct, field, macro-generated
    /// fn, or failing those at least an identifier in the file's code:
    /// lock names and tokens anchor on field identifiers). A waiver
    /// that outlives its code would silently shadow the *next* finding
    /// at that location, so staleness is itself a finding.
    pub fn stale_findings(&self, model: &crate::graph::CrateModel) -> Vec<Finding> {
        let mut out = Vec::new();
        for w in &self.entries {
            let Some(fi) = model.file_index(&w.file) else {
                out.push(Finding::new(
                    "analyze",
                    "stale-waiver",
                    &w.file,
                    1,
                    &w.symbol,
                    format!(
                        "waiver `{} {} {}` names a file that no longer exists; \
                         delete or update the entry in analyze.waivers",
                        w.rule, w.file, w.symbol
                    ),
                ));
                continue;
            };
            if w.symbol == "*" {
                continue;
            }
            let file = &model.files[fi];
            // Lock names are `<module>.<receiver>`: anchor on the
            // receiver identifier.
            let tail = w.symbol.rsplit('.').next().unwrap_or(&w.symbol);
            let known = file.fns.iter().any(|f| f.name == w.symbol)
                || file.structs.iter().any(|s| {
                    s.name == w.symbol || s.fields.iter().any(|(n, _)| n == &w.symbol)
                })
                || file.generated.iter().any(|g| g.name == w.symbol)
                || file.lines.iter().any(|l| {
                    crate::lexer::has_word(&l.code, &w.symbol)
                        || crate::lexer::has_word(&l.code, tail)
                });
            if !known {
                out.push(Finding::new(
                    "analyze",
                    "stale-waiver",
                    &w.file,
                    1,
                    &w.symbol,
                    format!(
                        "waiver `{} {} {}` names a symbol that no longer exists in \
                         the file; delete or update the entry in analyze.waivers",
                        w.rule, w.file, w.symbol
                    ),
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, file: &str, symbol: &str) -> Finding {
        Finding::new("determinism", rule, file, 3, symbol, "msg".to_string())
    }

    #[test]
    fn waivers_match_exact_and_wildcard_symbols() {
        let w = Waivers::parse(
            "# header comment\n\
             det-hash-iter graph/io.rs remap  # trailing comment\n\
             det-wall-clock algo/mod.rs *\n",
        )
        .unwrap();
        let mut fs = vec![
            finding("det-hash-iter", "graph/io.rs", "remap"),
            finding("det-hash-iter", "graph/io.rs", "first_weight"),
            finding("det-wall-clock", "algo/mod.rs", "exceeded"),
            finding("det-wall-clock", "serve/mod.rs", "exceeded"),
        ];
        assert_eq!(w.apply(&mut fs), 2);
        assert!(fs[0].waived, "exact symbol match");
        assert!(!fs[1].waived, "different symbol, no wildcard");
        assert!(fs[2].waived, "wildcard symbol");
        assert!(!fs[3].waived, "different file");
    }

    #[test]
    fn malformed_waiver_lines_are_errors_with_line_numbers() {
        let err = Waivers::parse("det-hash-iter graph/io.rs\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = Waivers::parse("ok x y\n\nrule file sym extra\n").unwrap_err();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn json_rendering_escapes_and_shapes() {
        let mut f = finding("det-hash-iter", "graph/io.rs", "remap");
        f.msg = "say \"hi\"\tok\n".to_string();
        let json = render_json(&[f]);
        assert!(json.starts_with('['), "{json}");
        assert!(json.ends_with(']'), "{json}");
        assert!(json.contains("\"pass\": \"determinism\""), "{json}");
        assert!(json.contains("\"line\": 3"), "{json}");
        assert!(json.contains("say \\\"hi\\\"\\tok\\n"), "{json}");
        assert!(json.contains("\"waived\": false"), "{json}");
        assert_eq!(render_json(&[]), "[\n]");
    }

    #[test]
    fn lint_violations_adapt_to_findings() {
        let v = Violation {
            file: "algo/x.rs".to_string(),
            line: 7,
            rule: "safety-comment",
            msg: "missing".to_string(),
        };
        let f = Finding::from_lint(v);
        assert_eq!(f.pass, "lint");
        assert_eq!(format!("{f}"), "algo/x.rs:7: [lint/safety-comment] missing");
    }
}
