//! The repo-invariant lint pass (`cargo xtask lint`).
//!
//! Clippy sees types and syntax; these rules encode *project* contracts
//! that live in comments and module boundaries, so they are enforced at
//! the source level with the shared lexer ([`crate::lexer`]) that strips
//! comments, string literals, and char literals before matching (a
//! `"unsafe"` inside a string or doc comment never trips a rule).
//!
//! Rules (scanned over `rust/src`; `#[cfg(test)]` regions are exempt
//! from R2–R4 — test code may use raw primitives and synthetic ids —
//! but **not** from R1, unsafety must be justified everywhere, and not
//! from R2 under the strict `rr/` paths):
//!
//! * **R1 `safety-comment`** — every `unsafe` token (block, fn, impl)
//!   carries a `// SAFETY:` comment or a `# Safety` doc section within
//!   the preceding [`SAFETY_WINDOW`] lines, stating the precondition it
//!   relies on.
//! * **R2 `ordering-comment`** — every `Ordering::Relaxed` outside
//!   tests carries an `// ORDERING:` justification within the preceding
//!   12 lines (either "the CAS word carries its whole payload" or "the
//!   data crosses the pool's mutex/condvar handshake" — see
//!   `runtime/sync`'s module docs). Under `rr/` (the compressed RR-set
//!   store, whose byte accounting backs OOM admission) the rule is
//!   strict: it applies inside `#[cfg(test)]` regions too.
//! * **R3 `facade-bypass`** — no direct `std::sync::Mutex`/`Condvar`/
//!   `RwLock` or `std::thread::{spawn, Builder, scope}` outside
//!   `runtime/` (which includes the `runtime/sync` facade) and
//!   `util/par.rs` (the scoped-thread substrate). Everything else goes
//!   through `crate::runtime::sync` so the loom build models it.
//! * **R4 `orig-id-hash`** — the PR 3 invariant: edge sampling hashes
//!   key off *original* vertex ids, never permuted ones. Every
//!   `edge_hash(...)` call site must reference `orig` in its argument
//!   window, and the body of `rebuild_sampling_tables` must call
//!   `orig(...)`.
//!
//! An unreadable file is reported as a `read-error` violation on line 1
//! and the walk continues, so one bad file cannot mask findings in the
//! rest of the tree.

use crate::lexer::{classify, comment_in_window, has_word, has_word_followed_by, test_mask};
use std::fmt;
use std::path::Path;

/// How far above an `unsafe` token a SAFETY justification may sit
/// (multi-bullet `# Safety` doc sections plus attributes need room).
const SAFETY_WINDOW: usize = 12;
/// How far above a `Relaxed` ordering an ORDERING justification may sit
/// (a little wider: CAS calls often span several wrapped lines).
const ORDERING_WINDOW: usize = 12;
/// How far below an `edge_hash(` call its arguments may wrap.
const HASH_ARG_WINDOW: usize = 2;
/// How far into `rebuild_sampling_tables` the `orig(...)` call must appear.
const REBUILD_BODY_WINDOW: usize = 25;

/// Raw primitives that must come from the `runtime::sync` facade instead.
const FACADE_BYPASS_TOKENS: [&str; 6] = [
    "std::sync::Mutex",
    "std::sync::Condvar",
    "std::sync::RwLock",
    "std::thread::spawn",
    "std::thread::Builder",
    "std::thread::scope",
];

/// Paths (relative to `rust/src`, `/`-separated) allowed to touch raw
/// sync primitives: the runtime layer (including the facade itself) and
/// the scoped-thread substrate.
fn facade_bypass_allowed(relpath: &str) -> bool {
    relpath.starts_with("runtime/") || relpath == "util/par.rs"
}

/// Paths where R2 (`ordering-comment`) applies even inside `#[cfg(test)]`
/// regions: the compressed RR-set store. Its byte accounting is what the
/// OOM admission check trusts, so even test-side relaxed atomics must say
/// why relaxed is enough.
fn ordering_strict(relpath: &str) -> bool {
    relpath.starts_with("rr/")
}

#[derive(Debug)]
pub struct Violation {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Lint one source file (`relpath` relative to the scan root, with `/`
/// separators). Pure so the fixture self-tests below can drive it.
pub fn check_source(relpath: &str, text: &str) -> Vec<Violation> {
    let lines = classify(text);
    let mask = test_mask(&lines);
    let mut out = Vec::new();
    let violation = |i: usize, rule: &'static str, msg: String| Violation {
        file: relpath.to_string(),
        line: i + 1,
        rule,
        msg,
    };

    for i in 0..lines.len() {
        let code = lines[i].code.as_str();

        // R1: unsafe needs a SAFETY justification — tests included.
        if has_word(code, "unsafe")
            && !comment_in_window(&lines, i, SAFETY_WINDOW, &["SAFETY:", "# Safety"])
        {
            out.push(violation(
                i,
                "safety-comment",
                "`unsafe` without a `// SAFETY:` comment or `# Safety` doc section in the \
                 preceding lines"
                    .to_string(),
            ));
        }

        // R2: Relaxed needs an ORDERING justification. Test regions are
        // exempt everywhere except the strict `rr/` paths.
        if (!mask[i] || ordering_strict(relpath))
            && has_word(code, "Relaxed")
            && !comment_in_window(&lines, i, ORDERING_WINDOW, &["ORDERING:"])
        {
            out.push(violation(
                i,
                "ordering-comment",
                "`Ordering::Relaxed` without an `// ORDERING:` justification in the \
                 preceding lines"
                    .to_string(),
            ));
        }

        if mask[i] {
            continue; // R3–R4 do not apply to #[cfg(test)] regions
        }

        // R3: raw sync primitives outside the runtime layer.
        if !facade_bypass_allowed(relpath) {
            for token in FACADE_BYPASS_TOKENS {
                if code.contains(token) {
                    out.push(violation(
                        i,
                        "facade-bypass",
                        format!("direct `{token}` — use `crate::runtime::sync` so the loom \
                                 build can model it"),
                    ));
                }
            }
        }

        // R4: hashes must key off original ids, not permuted ones.
        if has_word_followed_by(code, "edge_hash", b'(') && !code.contains("fn edge_hash") {
            let hi = (i + HASH_ARG_WINDOW).min(lines.len() - 1);
            let references_orig = lines[i..=hi].iter().any(|l| has_word(&l.code, "orig"));
            if !references_orig {
                out.push(violation(
                    i,
                    "orig-id-hash",
                    "`edge_hash(...)` call without `orig` in its argument window — edge \
                     sampling must hash original vertex ids (PR 3 invariant)"
                        .to_string(),
                ));
            }
        }
        if code.contains("fn rebuild_sampling_tables") {
            let hi = (i + REBUILD_BODY_WINDOW).min(lines.len() - 1);
            let calls_orig = lines[i..=hi].iter().any(|l| has_word_followed_by(&l.code, "orig", b'('));
            if !calls_orig {
                out.push(violation(
                    i,
                    "orig-id-hash",
                    "`rebuild_sampling_tables` body does not call `orig(...)` — sampling \
                     tables must be keyed off original vertex ids (PR 3 invariant)"
                        .to_string(),
                ));
            }
        }
    }
    out
}

/// Lint every `.rs` file under `root`, in sorted order. A file that
/// cannot be read yields a `read-error` violation for that file and the
/// walk continues — every other file is still fully reported.
pub fn check_tree(root: &Path) -> Result<Vec<Violation>, String> {
    let mut files = Vec::new();
    collect_rs_files(root, root, &mut files)?;
    if files.is_empty() {
        return Err(format!("no .rs files under {}", root.display()));
    }
    files.sort();
    let mut out = Vec::new();
    for rel in files {
        match std::fs::read_to_string(root.join(&rel)) {
            Ok(text) => out.extend(check_source(&rel, &text)),
            Err(e) => out.push(Violation {
                file: rel,
                line: 1,
                rule: "read-error",
                msg: format!("could not read file: {e}"),
            }),
        }
    }
    Ok(out)
}

pub(crate) fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .map_err(|e| e.to_string())?
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fixture self-tests: each rule must fire on a violating fixture and
// stay quiet on the corrected one (the ISSUE 6 acceptance demo).
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(relpath: &str, text: &str) -> Vec<&'static str> {
        check_source(relpath, text).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn safety_rule_fires_without_comment_and_passes_with_it() {
        let bad = "fn f(p: *mut u8) {\n    unsafe { *p = 1 };\n}\n";
        assert_eq!(rules("algo/x.rs", bad), vec!["safety-comment"]);

        let good = "fn f(p: *mut u8) {\n    // SAFETY: p is valid and exclusively owned here.\n    unsafe { *p = 1 };\n}\n";
        assert!(rules("algo/x.rs", good).is_empty());

        let doc = "/// # Safety\n/// Caller guarantees p is valid.\npub unsafe fn f(p: *mut u8) {}\n";
        assert!(rules("algo/x.rs", doc).is_empty());
    }

    #[test]
    fn safety_rule_applies_inside_test_modules_too() {
        let bad = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let mut x = 0u8;\n        unsafe { *(&mut x as *mut u8) = 1 };\n    }\n}\n";
        assert_eq!(rules("algo/x.rs", bad), vec!["safety-comment"]);
    }

    #[test]
    fn ordering_rule_fires_without_comment_and_passes_with_it() {
        let bad = "fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed)\n}\n";
        assert_eq!(rules("algo/x.rs", bad), vec!["ordering-comment"]);

        let good = "fn f(a: &AtomicUsize) -> usize {\n    // ORDERING: counter is only read after the pool handshake joins.\n    a.load(Ordering::Relaxed)\n}\n";
        assert!(rules("algo/x.rs", good).is_empty());
    }

    #[test]
    fn ordering_rule_exempts_test_regions() {
        let text = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        X.fetch_add(1, Ordering::Relaxed);\n    }\n}\n";
        assert!(rules("algo/x.rs", text).is_empty());
    }

    #[test]
    fn ordering_rule_is_strict_in_rr_paths_even_inside_tests() {
        // The `rr/` store's accounting backs OOM admission, so the test
        // exemption does not apply there: a bare Relaxed in a test module
        // must still carry its ORDERING justification.
        let bad = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        X.fetch_add(1, Ordering::Relaxed);\n    }\n}\n";
        assert_eq!(rules("rr/mod.rs", bad), vec!["ordering-comment"]);
        assert_eq!(rules("rr/codec.rs", bad), vec!["ordering-comment"]);

        let good = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        // ORDERING: test-local counter; the assert reads it after join.\n        X.fetch_add(1, Ordering::Relaxed);\n    }\n}\n";
        assert!(rules("rr/mod.rs", good).is_empty());

        // Non-test `rr/` code gets the ordinary (already strict) rule.
        let plain = "fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Relaxed)\n}\n";
        assert_eq!(rules("rr/mod.rs", plain), vec!["ordering-comment"]);
    }

    #[test]
    fn facade_bypass_fires_outside_runtime_and_passes_inside() {
        let text = "use std::sync::Mutex;\n";
        assert_eq!(rules("algo/x.rs", text), vec!["facade-bypass"]);
        assert!(rules("runtime/pool/mod.rs", text).is_empty());
        assert!(rules("runtime/sync/model.rs", text).is_empty());

        let scoped = "fn f() { std::thread::scope(|s| {}); }\n";
        assert_eq!(rules("labelprop/mod.rs", scoped), vec!["facade-bypass"]);
        assert!(rules("util/par.rs", scoped).is_empty());
    }

    #[test]
    fn orig_id_rule_fires_on_permuted_hash_and_passes_on_orig() {
        let bad = "fn w(g: &Graph, u: u32, v: u32) -> u32 {\n    edge_hash(u, v)\n}\n";
        assert_eq!(rules("graph/weights.rs", bad), vec!["orig-id-hash"]);

        let good = "fn w(g: &Graph, u: u32, v: u32) -> u32 {\n    edge_hash(g.orig(u), g.orig(v))\n}\n";
        assert!(rules("graph/weights.rs", good).is_empty());

        // Multi-line argument windows count.
        let wrapped = "fn w(g: &Graph, u: u32, v: u32) -> u32 {\n    edge_hash(\n        g.orig(u),\n        g.orig(v),\n    )\n}\n";
        assert!(rules("graph/weights.rs", wrapped).is_empty());
    }

    #[test]
    fn orig_id_rule_checks_rebuild_sampling_tables_body() {
        let bad = "impl Graph {\n    pub fn rebuild_sampling_tables(&mut self) {\n        for i in 0..self.adj.len() {\n            self.edge_hash.push(hash(i as u32));\n        }\n    }\n}\n";
        assert_eq!(rules("graph/mod.rs", bad), vec!["orig-id-hash"]);

        let good = "impl Graph {\n    pub fn rebuild_sampling_tables(&mut self) {\n        for i in 0..self.adj.len() {\n            self.edge_hash.push(edge_hash(self.orig(v), self.orig(self.adj[i])));\n        }\n    }\n}\n";
        assert!(rules("graph/mod.rs", good).is_empty());
    }

    #[test]
    fn field_access_is_not_a_hash_call() {
        // `graph.edge_hash[e]` is table indexing, not a keyed hash call.
        let text = "fn f(graph: &Graph, e: usize) -> u32 {\n    graph.edge_hash[e]\n}\n";
        assert!(rules("algo/fused.rs", text).is_empty());
    }

    #[test]
    fn lexer_ignores_strings_comments_and_char_literals() {
        // "unsafe"/"Relaxed" in strings and comments must not trip rules.
        let text = concat!(
            "fn f() {\n",
            "    let s = \"unsafe { Ordering::Relaxed }\";\n",
            "    let r = r#\"unsafe edge_hash(u, v)\"#;\n",
            "    let c = '\\'';\n",
            "    let lt: &'static str = s; // mentions unsafe and Relaxed\n",
            "    /* block comment: std::sync::Mutex, unsafe, Relaxed */\n",
            "}\n"
        );
        assert!(rules("algo/x.rs", text).is_empty());
    }

    #[test]
    fn lexer_survives_escaped_char_literals() {
        // `'\\'` must close at its real quote: the escaped character must
        // not re-trigger escape handling and swallow the closing quote
        // (and with it the code that follows — a rule-hiding lexer bug).
        let text = concat!(
            "fn f(ch: char, a: &A) -> bool {\n",
            "    let back = ch == '\\\\';\n",
            "    let quote = ch == '\\'';\n",
            "    let nl = ch == '\\n';\n",
            "    a.load(Ordering::Relaxed);\n",
            "    back || quote || nl\n",
            "}\n"
        );
        // The Relaxed on the line after the literals must still be seen.
        assert_eq!(rules("algo/x.rs", text), vec!["ordering-comment"]);
    }

    #[test]
    fn string_line_continuations_keep_line_numbers_aligned() {
        // A trailing-backslash string continuation spans two physical
        // lines; the lexer must still emit both lines so every report and
        // comment-window distance stays 1:1 with the file.
        let text = concat!(
            "fn f(a: &A) {\n",
            "    let s = \"first half \\\n",
            "             second half\";\n",
            "    a.load(Ordering::Relaxed);\n",
            "}\n"
        );
        let violations = check_source("algo/x.rs", text);
        assert_eq!(violations.len(), 1);
        assert_eq!(violations[0].line, 4, "line numbers must track the file");
    }

    #[test]
    fn lexer_still_sees_code_after_a_string_on_the_same_line() {
        let text = "fn f() { let s = \"x\"; unsafe { danger() } }\n";
        assert_eq!(rules("algo/x.rs", text), vec!["safety-comment"]);
    }

    #[test]
    fn nested_block_comments_terminate_correctly() {
        let text = "/* outer /* inner */ still comment */ fn f(a: &A) { a.load(Ordering::Relaxed); }\n";
        assert_eq!(rules("algo/x.rs", text), vec!["ordering-comment"]);
    }

    #[test]
    fn safety_window_is_bounded() {
        // A SAFETY comment 11+ lines above must NOT satisfy the rule —
        // stale justifications drifting away from their code are bugs.
        let mut text = String::from("// SAFETY: too far away.\n");
        for _ in 0..SAFETY_WINDOW {
            text.push_str("fn pad() {}\n");
        }
        text.push_str("fn f(p: *mut u8) { unsafe { *p = 1 }; }\n");
        assert_eq!(rules("algo/x.rs", &text), vec!["safety-comment"]);
    }

    #[test]
    fn multiple_violations_in_one_file_are_all_reported() {
        // One file, three independent violations — the pass must report
        // every one, not stop at the first.
        let text = concat!(
            "use std::sync::Mutex;\n",
            "fn f(p: *mut u8) {\n",
            "    unsafe { *p = 1 };\n",
            "}\n",
            "fn g(a: &AtomicUsize) -> usize {\n",
            "    a.load(Ordering::Relaxed)\n",
            "}\n"
        );
        let mut got = rules("algo/x.rs", text);
        got.sort();
        assert_eq!(got, vec!["facade-bypass", "ordering-comment", "safety-comment"]);
    }

    #[test]
    fn unreadable_file_is_a_read_error_not_an_abort() {
        // A tree with one good and one unreadable .rs entry: the good
        // file's violations still surface alongside the read-error.
        let dir = std::env::temp_dir().join("xtask_lint_read_error");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("good.rs"), "use std::sync::Mutex;\n").unwrap();
        // A directory named *.rs is unreadable as a file on every platform…
        // except it walks as a directory; use invalid UTF-8 instead, which
        // read_to_string rejects deterministically.
        std::fs::write(dir.join("bad.rs"), [0xFFu8, 0xFE, 0x00, 0xC0]).unwrap();
        let violations = check_tree(&dir).unwrap();
        let rules: Vec<&str> = violations.iter().map(|v| v.rule).collect();
        assert!(rules.contains(&"read-error"), "{rules:?}");
        assert!(rules.contains(&"facade-bypass"), "{rules:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
