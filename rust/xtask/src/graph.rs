//! Crate-level model for `xtask analyze`: all parsed source files, the
//! module graph (`mod x;` declarations), and an intra-crate call graph
//! with file-level reachability.
//!
//! Name resolution is deliberately approximate — no type checking, no
//! import tracking. A call `foo::bar(...)` resolves to definitions of
//! `bar` in files whose path matches the module `foo`; when no path
//! matches (the qualifier was a type, `Self`, or an external crate) it
//! falls back to *every* definition of `bar`, and bare/method calls
//! resolve to every definition too. That can only widen the reachable
//! set, which is the safe direction for a determinism gate: scope grows,
//! findings never silently disappear.

use crate::parser::{self, SourceFile};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

pub(crate) struct CrateModel {
    pub files: Vec<SourceFile>,
}

/// A function definition site: file index plus (for parsed fns) the
/// index into that file's `fns`. Macro-generated fns have no parsed
/// body and act as call-graph leaves.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Def {
    Parsed { file: usize, fn_idx: usize },
    Generated { file: usize },
}

impl CrateModel {
    /// Build the model from in-memory `(relpath, text)` pairs — the
    /// fixture-friendly constructor every pass self-test uses.
    pub fn from_sources(sources: &[(&str, &str)]) -> Self {
        let files = sources.iter().map(|(rel, text)| parser::parse(rel, text)).collect();
        Self { files }
    }

    /// Load every `.rs` file under `root`. Unreadable files become
    /// `(relpath, error)` pairs so the caller can report them as
    /// findings instead of aborting the whole run.
    pub fn load_tree(root: &Path) -> Result<(Self, Vec<(String, String)>), String> {
        let mut rels = Vec::new();
        crate::lint::collect_rs_files(root, root, &mut rels)?;
        if rels.is_empty() {
            return Err(format!("no .rs files under {}", root.display()));
        }
        rels.sort();
        let mut files = Vec::new();
        let mut errors = Vec::new();
        for rel in rels {
            match std::fs::read_to_string(root.join(&rel)) {
                Ok(text) => files.push(parser::parse(&rel, &text)),
                Err(e) => errors.push((rel, e.to_string())),
            }
        }
        Ok((Self { files }, errors))
    }

    pub fn file_index(&self, rel: &str) -> Option<usize> {
        self.files.iter().position(|f| f.rel == rel)
    }

    /// Child modules declared by `mod x;` in `files[idx]`: resolved to
    /// `<dir>/x.rs` or `<dir>/x/mod.rs` where `<dir>` is the declaring
    /// file's module directory.
    pub fn module_children(&self, idx: usize) -> Vec<usize> {
        let rel = &self.files[idx].rel;
        let dir = if rel == "lib.rs" || rel == "main.rs" {
            String::new()
        } else if let Some(stripped) = rel.strip_suffix("/mod.rs") {
            stripped.to_string()
        } else if let Some(stripped) = rel.strip_suffix(".rs") {
            stripped.to_string()
        } else {
            rel.clone()
        };
        let mut out = Vec::new();
        for name in &self.files[idx].mods {
            let flat = if dir.is_empty() { format!("{name}.rs") } else { format!("{dir}/{name}.rs") };
            let nested =
                if dir.is_empty() { format!("{name}/mod.rs") } else { format!("{dir}/{name}/mod.rs") };
            if let Some(c) = self.file_index(&flat).or_else(|| self.file_index(&nested)) {
                out.push(c);
            }
        }
        out
    }

    /// Name → definition sites, over non-test parsed fns and
    /// macro-generated fns. Aliases (`use m::f as g`) add the target's
    /// definitions under the alias name.
    fn fn_defs(&self) -> BTreeMap<String, Vec<Def>> {
        let mut defs: BTreeMap<String, Vec<Def>> = BTreeMap::new();
        for (fi, file) in self.files.iter().enumerate() {
            for (ki, f) in file.fns.iter().enumerate() {
                if !f.in_test {
                    defs.entry(f.name.clone()).or_default().push(Def::Parsed { file: fi, fn_idx: ki });
                }
            }
            for g in &file.generated {
                defs.entry(g.name.clone()).or_default().push(Def::Generated { file: fi });
            }
        }
        // One alias round is enough in practice (alias-of-alias chains
        // do not occur in this crate).
        let mut alias_defs: Vec<(String, Vec<Def>)> = Vec::new();
        for file in &self.files {
            for (target, alias) in &file.aliases {
                if alias != target {
                    if let Some(d) = defs.get(target) {
                        alias_defs.push((alias.clone(), d.clone()));
                    }
                }
            }
        }
        for (alias, d) in alias_defs {
            defs.entry(alias).or_default().extend(d);
        }
        for d in defs.values_mut() {
            d.sort();
            d.dedup();
        }
        defs
    }

    /// File indices reachable (via the call graph) from the `pub`
    /// entry-point functions of every file selected by `is_root`. Root
    /// files are always in the result (they are scanned whole at the
    /// file level); private helpers inside them are traversed as soon
    /// as any entry point calls them.
    pub fn reachable_files(&self, is_root: impl Fn(&SourceFile) -> bool) -> BTreeSet<usize> {
        let defs = self.fn_defs();
        let mut reachable_files = BTreeSet::new();
        let mut visited: BTreeSet<Def> = BTreeSet::new();
        let mut queue: Vec<Def> = Vec::new();
        for (fi, file) in self.files.iter().enumerate() {
            if is_root(file) {
                reachable_files.insert(fi);
                for (ki, f) in file.fns.iter().enumerate() {
                    if f.is_pub && !f.in_test {
                        queue.push(Def::Parsed { file: fi, fn_idx: ki });
                    }
                }
            }
        }
        while let Some(def) = queue.pop() {
            if !visited.insert(def) {
                continue;
            }
            let (fi, ki) = match def {
                Def::Generated { file } => {
                    reachable_files.insert(file);
                    continue;
                }
                Def::Parsed { file, fn_idx } => (file, fn_idx),
            };
            reachable_files.insert(fi);
            for call in &self.files[fi].fns[ki].calls {
                let Some(candidates) = defs.get(&call.name) else { continue };
                let narrowed: Vec<Def> = if call.is_method {
                    // Receiver types are unknown: resolve to every
                    // definition of the method name.
                    candidates.clone()
                } else {
                    match &call.qualifier {
                        Some(q) => {
                            let m: Vec<Def> = candidates
                                .iter()
                                .copied()
                                .filter(|d| {
                                    let file = match d {
                                        Def::Parsed { file, .. } | Def::Generated { file } => *file,
                                    };
                                    file_matches_module(&self.files[file].rel, q)
                                })
                                .collect();
                            // Qualifier was a type / Self / external
                            // path: fall back to every candidate.
                            if m.is_empty() { candidates.clone() } else { m }
                        }
                        None => candidates.clone(),
                    }
                };
                queue.extend(narrowed);
            }
        }
        reachable_files
    }
}

/// Does `rel` plausibly implement module `q`? Matches `q.rs`,
/// `.../q.rs`, `q/mod.rs`, and any file under a `q/` directory.
fn file_matches_module(rel: &str, q: &str) -> bool {
    rel == format!("{q}.rs")
        || rel.ends_with(&format!("/{q}.rs"))
        || rel == format!("{q}/mod.rs")
        || rel.ends_with(&format!("/{q}/mod.rs"))
        || rel.starts_with(&format!("{q}/"))
        || rel.contains(&format!("/{q}/"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CrateModel {
        CrateModel::from_sources(&[
            (
                "algo/mod.rs",
                "pub fn entry(g: u32) -> u32 {\n    helper::go(g) + local(g)\n}\nfn local(g: u32) -> u32 { g }\n",
            ),
            ("util/helper.rs", "pub fn go(g: u32) -> u32 {\n    deep(g)\n}\nfn deep(g: u32) -> u32 { g }\n"),
            ("util/unused.rs", "pub fn island(g: u32) -> u32 { g }\n"),
            (
                "simd/mod.rs",
                "mod avx2;\nmod scalar;\npub use avx2::row_w8 as veclabel_row_avx2;\n",
            ),
            (
                "simd/avx2.rs",
                concat!(
                    "macro_rules! gen_row {\n",
                    "    ($name:ident) => {\n",
                    "        /// # Safety\n",
                    "        pub unsafe fn $name() {}\n",
                    "    };\n",
                    "}\n",
                    "gen_row!(row_w8);\n",
                ),
            ),
            ("simd/scalar.rs", "pub fn row_scalar() {}\n"),
        ])
    }

    #[test]
    fn qualified_calls_reach_across_files_and_islands_stay_out() {
        let m = model();
        let reached = m.reachable_files(|f| f.rel.starts_with("algo/"));
        let names: Vec<&str> = reached.iter().map(|&i| m.files[i].rel.as_str()).collect();
        assert!(names.contains(&"algo/mod.rs"), "{names:?}");
        assert!(names.contains(&"util/helper.rs"), "qualified call resolves: {names:?}");
        assert!(!names.contains(&"util/unused.rs"), "island not reachable: {names:?}");
    }

    #[test]
    fn aliases_resolve_to_generated_fns() {
        let m = CrateModel::from_sources(&[
            ("algo/mod.rs", "pub fn entry() {\n    veclabel_row_avx2()\n}\n"),
            (
                "simd/mod.rs",
                "mod avx2;\npub use avx2::row_w8 as veclabel_row_avx2;\n",
            ),
            (
                "simd/avx2.rs",
                "macro_rules! gen_row {\n    ($name:ident) => {\n        pub unsafe fn $name() {}\n    };\n}\ngen_row!(row_w8);\n",
            ),
        ]);
        let reached = m.reachable_files(|f| f.rel.starts_with("algo/"));
        let names: Vec<&str> = reached.iter().map(|&i| m.files[i].rel.as_str()).collect();
        assert!(names.contains(&"simd/avx2.rs"), "alias → generated fn: {names:?}");
    }

    #[test]
    fn test_only_callers_do_not_seed_reachability() {
        let m = CrateModel::from_sources(&[
            (
                "algo/mod.rs",
                "pub fn entry() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { crate::util::secret::hidden() }\n}\n",
            ),
            ("util/secret.rs", "pub fn hidden() {}\n"),
        ]);
        let reached = m.reachable_files(|f| f.rel.starts_with("algo/"));
        let names: Vec<&str> = reached.iter().map(|&i| m.files[i].rel.as_str()).collect();
        assert!(!names.contains(&"util/secret.rs"), "{names:?}");
    }

    #[test]
    fn module_children_resolve_flat_and_nested() {
        let m = model();
        let simd = m.file_index("simd/mod.rs").unwrap();
        let kids: Vec<&str> =
            m.module_children(simd).iter().map(|&i| m.files[i].rel.as_str()).collect();
        assert_eq!(kids, vec!["simd/avx2.rs", "simd/scalar.rs"]);
    }

    #[test]
    fn load_tree_reports_unreadable_files_without_aborting() {
        let dir = std::env::temp_dir().join(format!("xtask-graph-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("ok.rs"), "pub fn fine() {}\n").unwrap();
        std::fs::write(dir.join("bad.rs"), [0xFFu8, 0xFE, 0x00, 0xC0]).unwrap();
        let (model, errors) = CrateModel::load_tree(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(model.files.len(), 1);
        assert_eq!(model.files[0].rel, "ok.rs");
        assert_eq!(errors.len(), 1);
        assert_eq!(errors[0].0, "bad.rs");
    }
}
