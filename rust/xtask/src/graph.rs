//! Crate-level model for `xtask analyze`: all parsed source files, the
//! module graph (`mod x;` declarations), and an intra-crate call graph
//! with file-level reachability.
//!
//! Name resolution is deliberately approximate — no type checking, no
//! import tracking. A call `foo::bar(...)` resolves to definitions of
//! `bar` in files whose path matches the module `foo` *or* whose
//! enclosing `impl` self-type is `foo` (so `ImSession::prepare` finds
//! the method, and `Self::f` resolves through the caller's own impl
//! block); when nothing matches (an external crate path) it falls back
//! to *every* definition of `bar`, and bare/method calls resolve to
//! every definition too. That can only widen the reachable set, which
//! is the safe direction for a reachability gate: scope grows, findings
//! never silently disappear. Passes that must *not* over-approximate
//! (lock-discipline's acquisition propagation fabricating edges) use
//! [`CallGraph::resolve`] directly and act only on unique resolutions.

use crate::parser::{self, CallRef, FnItem, SourceFile};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

pub(crate) struct CrateModel {
    pub files: Vec<SourceFile>,
}

/// A function definition site: file index plus (for parsed fns) the
/// index into that file's `fns`. Macro-generated fns have no parsed
/// body and act as call-graph leaves.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) enum Def {
    Parsed { file: usize, fn_idx: usize },
    Generated { file: usize },
}

impl Def {
    pub fn file(self) -> usize {
        match self {
            Def::Parsed { file, .. } | Def::Generated { file } => file,
        }
    }
}

impl CrateModel {
    /// Build the model from in-memory `(relpath, text)` pairs — the
    /// fixture-friendly constructor every pass self-test uses.
    pub fn from_sources(sources: &[(&str, &str)]) -> Self {
        let files = sources.iter().map(|(rel, text)| parser::parse(rel, text)).collect();
        Self { files }
    }

    /// Load every `.rs` file under `root`. Unreadable files become
    /// `(relpath, error)` pairs so the caller can report them as
    /// findings instead of aborting the whole run.
    pub fn load_tree(root: &Path) -> Result<(Self, Vec<(String, String)>), String> {
        let mut rels = Vec::new();
        crate::lint::collect_rs_files(root, root, &mut rels)?;
        if rels.is_empty() {
            return Err(format!("no .rs files under {}", root.display()));
        }
        rels.sort();
        let mut files = Vec::new();
        let mut errors = Vec::new();
        for rel in rels {
            match std::fs::read_to_string(root.join(&rel)) {
                Ok(text) => files.push(parser::parse(&rel, &text)),
                Err(e) => errors.push((rel, e.to_string())),
            }
        }
        Ok((Self { files }, errors))
    }

    pub fn file_index(&self, rel: &str) -> Option<usize> {
        self.files.iter().position(|f| f.rel == rel)
    }

    /// Child modules declared by `mod x;` in `files[idx]`: resolved to
    /// `<dir>/x.rs` or `<dir>/x/mod.rs` where `<dir>` is the declaring
    /// file's module directory.
    pub fn module_children(&self, idx: usize) -> Vec<usize> {
        let rel = &self.files[idx].rel;
        let dir = if rel == "lib.rs" || rel == "main.rs" {
            String::new()
        } else if let Some(stripped) = rel.strip_suffix("/mod.rs") {
            stripped.to_string()
        } else if let Some(stripped) = rel.strip_suffix(".rs") {
            stripped.to_string()
        } else {
            rel.clone()
        };
        let mut out = Vec::new();
        for name in &self.files[idx].mods {
            let flat = if dir.is_empty() { format!("{name}.rs") } else { format!("{dir}/{name}.rs") };
            let nested =
                if dir.is_empty() { format!("{name}/mod.rs") } else { format!("{dir}/{name}/mod.rs") };
            if let Some(c) = self.file_index(&flat).or_else(|| self.file_index(&nested)) {
                out.push(c);
            }
        }
        out
    }

    /// Name → definition sites, over non-test parsed fns and
    /// macro-generated fns. Aliases (`use m::f as g`) add the target's
    /// definitions under the alias name.
    fn fn_defs(&self) -> BTreeMap<String, Vec<Def>> {
        let mut defs: BTreeMap<String, Vec<Def>> = BTreeMap::new();
        for (fi, file) in self.files.iter().enumerate() {
            for (ki, f) in file.fns.iter().enumerate() {
                if !f.in_test {
                    defs.entry(f.name.clone()).or_default().push(Def::Parsed { file: fi, fn_idx: ki });
                }
            }
            for g in &file.generated {
                defs.entry(g.name.clone()).or_default().push(Def::Generated { file: fi });
            }
        }
        // One alias round is enough in practice (alias-of-alias chains
        // do not occur in this crate).
        let mut alias_defs: Vec<(String, Vec<Def>)> = Vec::new();
        for file in &self.files {
            for (target, alias) in &file.aliases {
                if alias != target {
                    if let Some(d) = defs.get(target) {
                        alias_defs.push((alias.clone(), d.clone()));
                    }
                }
            }
        }
        for (alias, d) in alias_defs {
            defs.entry(alias).or_default().extend(d);
        }
        for d in defs.values_mut() {
            d.sort();
            d.dedup();
        }
        defs
    }

    /// The resolver + BFS front-end the passes share. Builds the
    /// name → definitions index once, plus a crate-global type-alias
    /// map (`pub use runtime::pool::WorkerPool as ThreadPool`) so a
    /// `ThreadPool::with_schedule(..)` call matches the `impl
    /// WorkerPool` definition. Only CamelCase pairs are kept: the
    /// parser also records `x as usize` cast pairs, which must not
    /// become qualifier synonyms.
    pub fn call_graph(&self) -> CallGraph<'_> {
        let mut type_aliases: BTreeMap<String, Vec<String>> = BTreeMap::new();
        for file in &self.files {
            for (target, alias) in &file.aliases {
                let camel = |s: &String| s.starts_with(|c: char| c.is_ascii_uppercase());
                if camel(target) && camel(alias) && target != alias {
                    let entry = type_aliases.entry(alias.clone()).or_default();
                    if !entry.contains(target) {
                        entry.push(target.clone());
                    }
                }
            }
        }
        CallGraph { model: self, defs: self.fn_defs(), type_aliases }
    }

    /// File indices reachable (via the call graph) from the `pub`
    /// entry-point functions of every file selected by `is_root`. Root
    /// files are always in the result (they are scanned whole at the
    /// file level); private helpers inside them are traversed as soon
    /// as any entry point calls them.
    pub fn reachable_files(&self, is_root: impl Fn(&SourceFile) -> bool) -> BTreeSet<usize> {
        let cg = self.call_graph();
        let mut out = BTreeSet::new();
        let mut seeds = Vec::new();
        for (fi, file) in self.files.iter().enumerate() {
            if is_root(file) {
                out.insert(fi);
                seeds.extend(cg.fns_in_file(fi, |f| f.is_pub));
            }
        }
        out.extend(cg.reachable_fns(seeds).into_iter().map(Def::file));
        out
    }
}

/// Call-graph front-end: qualifier/owner-restricted resolution with the
/// widen-to-all fallback, plus fn-level reachability.
pub(crate) struct CallGraph<'a> {
    pub model: &'a CrateModel,
    defs: BTreeMap<String, Vec<Def>>,
    /// alias → original type names, from CamelCase `use .. as ..` pairs.
    type_aliases: BTreeMap<String, Vec<String>>,
}

impl<'a> CallGraph<'a> {
    /// The parsed item behind a `Def`, when it has one (generated fns
    /// are leaves without bodies).
    pub fn fn_item(&self, def: Def) -> Option<&'a FnItem> {
        match def {
            Def::Parsed { file, fn_idx } => Some(&self.model.files[file].fns[fn_idx]),
            Def::Generated { .. } => None,
        }
    }

    /// Non-test fns of `files[fi]` passing `pred`, as seeds.
    pub fn fns_in_file(&self, fi: usize, pred: impl Fn(&FnItem) -> bool) -> Vec<Def> {
        self.model.files[fi]
            .fns
            .iter()
            .enumerate()
            .filter(|(_, f)| !f.in_test && pred(f))
            .map(|(ki, _)| Def::Parsed { file: fi, fn_idx: ki })
            .collect()
    }

    /// Every definition site a call from `caller` may land on.
    ///
    /// * method calls (`recv.name(..)`): every definition of the name —
    ///   receiver types are unknown, and trait-object dispatch means any
    ///   impl could be the target;
    /// * `Q::name(..)`: definitions whose file matches module `Q` *or*
    ///   whose impl self-type is `Q`; `Self::name(..)` substitutes the
    ///   caller's own impl type; when the restriction matches nothing
    ///   (external path), widen to every definition;
    /// * bare `name(..)`: every definition.
    pub fn resolve(&self, caller: Def, call: &CallRef) -> Vec<Def> {
        let Some(candidates) = self.defs.get(&call.name) else { return Vec::new() };
        if call.is_method {
            return candidates.clone();
        }
        let Some(q) = call.qualifier.as_deref() else { return candidates.clone() };
        let q: &str = if q == "Self" {
            match self.fn_item(caller).and_then(|f| f.owner.as_deref()) {
                Some(owner) => owner,
                None => return candidates.clone(),
            }
        } else {
            q
        };
        let narrowed: Vec<Def> =
            candidates.iter().copied().filter(|&d| self.qualifier_matches(d, q)).collect();
        if narrowed.is_empty() { candidates.clone() } else { narrowed }
    }

    /// Does definition `d` plausibly belong to qualifier `q` — its file
    /// matches module `q`, its impl self-type is `q`, or either holds
    /// for a type `q` aliases (`ThreadPool` → `WorkerPool`)?
    fn qualifier_matches(&self, d: Def, q: &str) -> bool {
        let names =
            std::iter::once(q).chain(self.type_aliases.get(q).into_iter().flatten().map(String::as_str));
        for n in names {
            if file_matches_module(&self.model.files[d.file()].rel, n)
                || self.fn_item(d).is_some_and(|f| f.owner.as_deref() == Some(n))
            {
                return true;
            }
        }
        false
    }

    /// Strict variant for passes that must *not* over-approximate: the
    /// unique target of `call`, or `None`. Unlike [`CallGraph::resolve`]
    /// there is no widen-to-all fallback — a qualified call whose
    /// restriction matches nothing (`File::open`, `Arc::clone`, any
    /// external path that happens to share a name with a crate fn) is
    /// unresolved, not "uniquely" the unrelated crate fn. Lock-order
    /// propagation uses this: a fabricated edge would fabricate an
    /// ordering violation.
    pub fn resolve_strict(&self, caller: Def, call: &CallRef) -> Option<Def> {
        let candidates = self.defs.get(&call.name)?;
        if call.is_method || call.qualifier.is_none() {
            return match candidates.as_slice() {
                [only] => Some(*only),
                _ => None,
            };
        }
        let q = call.qualifier.as_deref()?;
        let q: &str = if q == "Self" {
            self.fn_item(caller).and_then(|f| f.owner.as_deref())?
        } else {
            q
        };
        let narrowed: Vec<Def> =
            candidates.iter().copied().filter(|&d| self.qualifier_matches(d, q)).collect();
        match narrowed.as_slice() {
            [only] => Some(*only),
            _ => None,
        }
    }

    /// Fn-level BFS over [`CallGraph::resolve`] from `seeds` (which are
    /// included in the result).
    pub fn reachable_fns(&self, seeds: Vec<Def>) -> BTreeSet<Def> {
        let mut visited: BTreeSet<Def> = BTreeSet::new();
        let mut queue = seeds;
        while let Some(def) = queue.pop() {
            if !visited.insert(def) {
                continue;
            }
            let Some(item) = self.fn_item(def) else { continue };
            for call in &item.calls {
                queue.extend(self.resolve(def, call));
            }
        }
        visited
    }
}

/// Does `rel` plausibly implement module `q`? Matches `q.rs`,
/// `.../q.rs`, `q/mod.rs`, and any file under a `q/` directory.
fn file_matches_module(rel: &str, q: &str) -> bool {
    rel == format!("{q}.rs")
        || rel.ends_with(&format!("/{q}.rs"))
        || rel == format!("{q}/mod.rs")
        || rel.ends_with(&format!("/{q}/mod.rs"))
        || rel.starts_with(&format!("{q}/"))
        || rel.contains(&format!("/{q}/"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CrateModel {
        CrateModel::from_sources(&[
            (
                "algo/mod.rs",
                "pub fn entry(g: u32) -> u32 {\n    helper::go(g) + local(g)\n}\nfn local(g: u32) -> u32 { g }\n",
            ),
            ("util/helper.rs", "pub fn go(g: u32) -> u32 {\n    deep(g)\n}\nfn deep(g: u32) -> u32 { g }\n"),
            ("util/unused.rs", "pub fn island(g: u32) -> u32 { g }\n"),
            (
                "simd/mod.rs",
                "mod avx2;\nmod scalar;\npub use avx2::row_w8 as veclabel_row_avx2;\n",
            ),
            (
                "simd/avx2.rs",
                concat!(
                    "macro_rules! gen_row {\n",
                    "    ($name:ident) => {\n",
                    "        /// # Safety\n",
                    "        pub unsafe fn $name() {}\n",
                    "    };\n",
                    "}\n",
                    "gen_row!(row_w8);\n",
                ),
            ),
            ("simd/scalar.rs", "pub fn row_scalar() {}\n"),
        ])
    }

    #[test]
    fn qualified_calls_reach_across_files_and_islands_stay_out() {
        let m = model();
        let reached = m.reachable_files(|f| f.rel.starts_with("algo/"));
        let names: Vec<&str> = reached.iter().map(|&i| m.files[i].rel.as_str()).collect();
        assert!(names.contains(&"algo/mod.rs"), "{names:?}");
        assert!(names.contains(&"util/helper.rs"), "qualified call resolves: {names:?}");
        assert!(!names.contains(&"util/unused.rs"), "island not reachable: {names:?}");
    }

    #[test]
    fn aliases_resolve_to_generated_fns() {
        let m = CrateModel::from_sources(&[
            ("algo/mod.rs", "pub fn entry() {\n    veclabel_row_avx2()\n}\n"),
            (
                "simd/mod.rs",
                "mod avx2;\npub use avx2::row_w8 as veclabel_row_avx2;\n",
            ),
            (
                "simd/avx2.rs",
                "macro_rules! gen_row {\n    ($name:ident) => {\n        pub unsafe fn $name() {}\n    };\n}\ngen_row!(row_w8);\n",
            ),
        ]);
        let reached = m.reachable_files(|f| f.rel.starts_with("algo/"));
        let names: Vec<&str> = reached.iter().map(|&i| m.files[i].rel.as_str()).collect();
        assert!(names.contains(&"simd/avx2.rs"), "alias → generated fn: {names:?}");
    }

    #[test]
    fn test_only_callers_do_not_seed_reachability() {
        let m = CrateModel::from_sources(&[
            (
                "algo/mod.rs",
                "pub fn entry() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { crate::util::secret::hidden() }\n}\n",
            ),
            ("util/secret.rs", "pub fn hidden() {}\n"),
        ]);
        let reached = m.reachable_files(|f| f.rel.starts_with("algo/"));
        let names: Vec<&str> = reached.iter().map(|&i| m.files[i].rel.as_str()).collect();
        assert!(!names.contains(&"util/secret.rs"), "{names:?}");
    }

    #[test]
    fn owner_and_self_qualifiers_narrow_resolution() {
        let m = CrateModel::from_sources(&[
            ("serve/pool.rs", "pub fn open() {\n    ImSession::prepare()\n}\n"),
            (
                "api/session.rs",
                concat!(
                    "pub struct ImSession;\n",
                    "impl ImSession {\n",
                    "    pub fn prepare() { Self::prepare_cow() }\n",
                    "    fn prepare_cow() { helper::deep() }\n",
                    "}\n",
                ),
            ),
            ("util/helper.rs", "pub fn deep() {}\n"),
            (
                "gen/other.rs",
                "fn quiet() {}\npub fn prepare() { quiet() }\npub fn prepare_cow() { quiet() }\n",
            ),
        ]);
        let cg = m.call_graph();
        let serve = m.file_index("serve/pool.rs").unwrap();
        let reached = cg.reachable_fns(cg.fns_in_file(serve, |f| f.is_pub));
        let files: BTreeSet<&str> =
            reached.iter().map(|d| m.files[d.file()].rel.as_str()).collect();
        assert!(files.contains("api/session.rs"), "{files:?}");
        assert!(files.contains("util/helper.rs"), "{files:?}");
        assert!(
            !files.contains("gen/other.rs"),
            "owner narrowing keeps same-name decoys out: {files:?}"
        );
    }

    #[test]
    fn method_calls_still_widen_to_every_definition() {
        let m = CrateModel::from_sources(&[
            ("serve/mod.rs", "pub fn dispatch(s: S) {\n    s.query()\n}\n"),
            (
                "api/session.rs",
                "pub struct A;\nimpl A {\n    pub fn query(&self) { leaf() }\n}\nfn leaf() {}\n",
            ),
        ]);
        let cg = m.call_graph();
        let serve = m.file_index("serve/mod.rs").unwrap();
        let reached = cg.reachable_fns(cg.fns_in_file(serve, |f| f.is_pub));
        let files: BTreeSet<&str> =
            reached.iter().map(|d| m.files[d.file()].rel.as_str()).collect();
        assert!(files.contains("api/session.rs"), "trait-object-safe widening: {files:?}");
    }

    #[test]
    fn type_aliased_qualifiers_resolve_strictly_through_the_alias() {
        let m = CrateModel::from_sources(&[
            (
                "util/par.rs",
                "pub use crate::runtime::pool::{Schedule, WorkerPool as ThreadPool};\n",
            ),
            (
                "api/session.rs",
                "pub fn prepare_cow(t: usize) {\n    let pool = ThreadPool::with_schedule(t);\n    drop(pool);\n}\n",
            ),
            (
                "runtime/pool/mod.rs",
                "pub struct WorkerPool;\nimpl WorkerPool {\n    pub fn with_schedule(_t: usize) -> Self {\n        WorkerPool\n    }\n}\n",
            ),
            ("gen/decoy.rs", "pub fn with_schedule() {}\n"),
        ]);
        let cg = m.call_graph();
        let api = m.file_index("api/session.rs").unwrap();
        let caller = cg.fns_in_file(api, |f| f.name == "prepare_cow")[0];
        let call =
            cg.fn_item(caller).unwrap().calls.iter().find(|c| c.name == "with_schedule").unwrap();
        let target = cg.resolve_strict(caller, call).expect("alias-qualified call resolves");
        assert_eq!(m.files[target.file()].rel, "runtime/pool/mod.rs");
    }

    #[test]
    fn strict_resolution_never_widens_through_foreign_qualifiers() {
        let m = CrateModel::from_sources(&[
            (
                "runtime/xla_engine.rs",
                "pub fn compiled() {\n    std::fs::File::open()\n}\n",
            ),
            ("serve/pool.rs", "pub fn open() {}\n"),
        ]);
        let cg = m.call_graph();
        let engine = m.file_index("runtime/xla_engine.rs").unwrap();
        let caller = cg.fns_in_file(engine, |f| f.name == "compiled")[0];
        let call = cg.fn_item(caller).unwrap().calls.iter().find(|c| c.name == "open").unwrap();
        assert_eq!(
            cg.resolve(caller, call).len(),
            1,
            "reachability widens File::open to the crate's only `open`"
        );
        assert_eq!(
            cg.resolve_strict(caller, call),
            None,
            "strict resolution must not claim File::open is SessionPool::open"
        );
    }

    #[test]
    fn module_children_resolve_flat_and_nested() {
        let m = model();
        let simd = m.file_index("simd/mod.rs").unwrap();
        let kids: Vec<&str> =
            m.module_children(simd).iter().map(|&i| m.files[i].rel.as_str()).collect();
        assert_eq!(kids, vec!["simd/avx2.rs", "simd/scalar.rs"]);
    }

    #[test]
    fn load_tree_reports_unreadable_files_without_aborting() {
        let dir = std::env::temp_dir().join(format!("xtask-graph-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("ok.rs"), "pub fn fine() {}\n").unwrap();
        std::fs::write(dir.join("bad.rs"), [0xFFu8, 0xFE, 0x00, 0xC0]).unwrap();
        let (model, errors) = CrateModel::load_tree(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(model.files.len(), 1);
        assert_eq!(model.files[0].rel, "ok.rs");
        assert_eq!(errors.len(), 1);
        assert_eq!(errors[0].0, "bad.rs");
    }
}
