//! The shared source-level lexer behind `xtask lint` and `xtask analyze`.
//!
//! Splits every source line into *code text* (with comments, string
//! literals, and char literals blanked out) and *comment text*, so the
//! rule passes can match tokens without tripping on `"unsafe"` inside a
//! string or a doc comment. Extracted from the PR 6 lint pass; the item
//! parser ([`crate::parser`]) builds on the same per-line model.

/// One source line after lexing: `code` with comments/strings/chars
/// blanked out, `comment` holding only comment text (line, block, doc).
pub(crate) struct Line {
    pub(crate) code: String,
    pub(crate) comment: String,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    Code,
    /// `// ...` until end of line.
    LineComment,
    /// `/* ... */`, nesting depth.
    BlockComment(u32),
    /// `"..."` with backslash escapes.
    Str,
    /// `r"..."` / `r##"..."##`, closing needs this many `#`s.
    RawStr(u32),
    /// `'x'` / `'\n'` with backslash escapes.
    CharLit,
}

/// Lex `text` into per-line code/comment split. Handles nested block
/// comments, raw strings, byte strings, and the char-literal/lifetime
/// ambiguity (`'a'` is a literal, `<'a>` is not).
pub(crate) fn classify(text: &str) -> Vec<Line> {
    let chars: Vec<char> = text.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let ch = chars[i];
        if ch == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            lines.push(Line { code: std::mem::take(&mut code), comment: std::mem::take(&mut comment) });
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied();
                if ch == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    i += 2;
                } else if ch == '/' && next == Some('*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if ch == '"' {
                    mode = Mode::Str;
                    code.push(' ');
                    i += 1;
                } else if (ch == 'r' || ch == 'b')
                    && !code.chars().last().is_some_and(is_ident_char)
                {
                    // Possible raw/byte-string prefix: b" r" br" r#" br#" ...
                    let mut j = i;
                    if chars.get(j) == Some(&'b') {
                        j += 1;
                    }
                    let raw = chars.get(j) == Some(&'r');
                    if raw {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if raw && chars.get(j) == Some(&'"') {
                        mode = Mode::RawStr(hashes);
                        code.push(' ');
                        i = j + 1;
                    } else if ch == 'b' && chars.get(i + 1) == Some(&'"') {
                        mode = Mode::Str;
                        code.push(' ');
                        i += 2;
                    } else {
                        code.push(ch);
                        i += 1;
                    }
                } else if ch == '\'' {
                    if next == Some('\\') {
                        mode = Mode::CharLit;
                        code.push(' ');
                        // Consume the quote, the backslash, AND the escaped
                        // character, so `'\\'` / `'\''` cannot re-trigger
                        // escape handling on the escaped character itself.
                        i += 3;
                    } else if next.is_some() && chars.get(i + 2) == Some(&'\'') {
                        // 'x' — a one-char literal.
                        code.push(' ');
                        i += 3;
                    } else {
                        // A lifetime; keep scanning as code.
                        code.push(ch);
                        i += 1;
                    }
                } else {
                    code.push(ch);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(ch);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if ch == '/' && next == Some('*') {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else if ch == '*' && next == Some('/') {
                    mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    comment.push(ch);
                    i += 1;
                }
            }
            Mode::Str => {
                if ch == '\\' {
                    // Skip the escaped character — except a line
                    // continuation's newline, which must still flush the
                    // physical line above (line numbers stay 1:1 with the
                    // file).
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if ch == '"' {
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if ch == '"' && (0..hashes).all(|k| chars.get(i + 1 + k as usize) == Some(&'#')) {
                    mode = Mode::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
            Mode::CharLit => {
                // The opening quote, backslash, and escaped character are
                // already consumed; scan for the closing quote (loose
                // enough for multi-char escapes like `'\u{7fff}'`).
                if ch == '\'' {
                    mode = Mode::Code;
                }
                i += 1;
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(Line { code, comment });
    }
    lines
}

pub(crate) fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

pub(crate) fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// True when `word` occurs in `code` with non-identifier characters (or
/// line boundaries) on both sides. Byte-wise so non-ASCII in `code`
/// cannot cause slicing trouble.
pub(crate) fn has_word(code: &str, word: &str) -> bool {
    word_position(code, word).is_some()
}

pub(crate) fn word_position(code: &str, word: &str) -> Option<usize> {
    let c = code.as_bytes();
    let w = word.as_bytes();
    if w.is_empty() || c.len() < w.len() {
        return None;
    }
    for i in 0..=c.len() - w.len() {
        if &c[i..i + w.len()] == w {
            let before_ok = i == 0 || !is_ident_byte(c[i - 1]);
            let after = i + w.len();
            let after_ok = after >= c.len() || !is_ident_byte(c[after]);
            if before_ok && after_ok {
                return Some(i);
            }
        }
    }
    None
}

/// True when `word` occurs as an identifier immediately followed by
/// `follow` (e.g. a call: `edge_hash(`).
pub(crate) fn has_word_followed_by(code: &str, word: &str, follow: u8) -> bool {
    let c = code.as_bytes();
    let w = word.as_bytes();
    if w.is_empty() || c.len() < w.len() + 1 {
        return false;
    }
    for i in 0..=c.len() - w.len() - 1 {
        if &c[i..i + w.len()] == w
            && (i == 0 || !is_ident_byte(c[i - 1]))
            && c[i + w.len()] == follow
        {
            return true;
        }
    }
    false
}

/// Mark the lines belonging to `#[cfg(test)]`-gated items: from the
/// attribute line through the matching close brace of the item's body
/// (found by brace counting over code text — string/char contents were
/// already blanked by the lexer).
pub(crate) fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        if !lines[i].code.contains("cfg(test") {
            i += 1;
            continue;
        }
        let start = i;
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            for ch in lines[j].code.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        let end = j.min(lines.len().saturating_sub(1));
        for flag in &mut mask[start..=end] {
            *flag = true;
        }
        i = end + 1;
    }
    mask
}

/// True when any line in `lines[lo..=i]` (where `lo = i - window`,
/// clamped) carries a comment containing one of `needles`. The shared
/// "justification comment within N lines above" check used by every
/// annotation rule (SAFETY / ORDERING / DETERMINISM).
pub(crate) fn comment_in_window(lines: &[Line], i: usize, window: usize, needles: &[&str]) -> bool {
    lines[i.saturating_sub(window)..=i]
        .iter()
        .any(|l| needles.iter().any(|n| l.comment.contains(n)))
}
