//! END-TO-END three-layer driver — the repository's integration proof.
//!
//! Exercises the full stack on a real workload:
//!
//!   L1  Pallas VECLABEL kernel (authored in python/compile/kernels/)
//!   L2  JAX lp_converge / mg_compute models wrapping it
//!   —   AOT-lowered to HLO text by `make artifacts` (python runs ONCE)
//!   L3  this Rust process: loads the artifacts via PJRT, runs INFUSER-MG
//!       seed selection end to end with the XLA engine, cross-checks
//!       every intermediate against the native Rust engine, and reports
//!       latency/throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_pipeline
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use infuser::algo::infuser::{DenseMemo, InfuserMg, InfuserParams};
use infuser::algo::{oracle, Budget};
use infuser::engine::{Engine, NativeEngine};
use infuser::gen::{self, GenSpec};
use infuser::graph::WeightModel;
use infuser::labelprop::PropagateOpts;
use infuser::runtime::XlaEngine;
use infuser::util::Timer;

fn main() -> infuser::Result<()> {
    // ---- Workload: a 12k-vertex R-MAT social-style network (fits the
    // n=16384 / m2=131072 artifact bucket).
    let graph = gen::generate(&GenSpec::rmat(14, 60_000, 77))
        .with_weights(WeightModel::Const(0.05), 3);
    let n = graph.num_vertices();
    let m2 = graph.adj.len();
    println!("workload: n={n} m={} (directed copies {m2})", graph.num_edges());

    let xla = XlaEngine::discover()?;
    println!("artifacts: {} entries from {}", xla.artifacts().entries.len(), xla.artifacts().dir.display());

    let opts = PropagateOpts { r_count: 64, seed: 9, threads: 4, ..Default::default() };

    // ---- Stage A: propagation on both engines; fixpoints must be
    // bit-identical (the determinism contract).
    let t = Timer::start();
    let native = NativeEngine.propagate(&graph, &opts)?;
    let native_secs = t.secs();
    let t = Timer::start();
    let xla_prop = xla.propagate(&graph, &opts)?; // compile + execute
    let xla_cold = t.secs();
    let t = Timer::start();
    let xla_prop2 = xla.propagate(&graph, &opts)?; // executable cached
    let xla_warm = t.secs();

    anyhow::ensure!(
        native.labels.data == xla_prop.labels.data,
        "native and XLA label matrices differ"
    );
    anyhow::ensure!(xla_prop.labels.data == xla_prop2.labels.data, "XLA run not deterministic");
    println!("\nstage A — propagation fixpoint (n={n}, R=64):");
    println!("  native  {native_secs:>8.3}s   ({} frontier iterations)", native.iterations);
    println!("  xla     {xla_cold:>8.3}s cold (compile+run), {xla_warm:.3}s warm ({} Jacobi sweeps)", xla_prop.iterations);
    println!("  fixpoints BIT-IDENTICAL across engines");

    // ---- Stage B: memoized marginal gains through the mg_compute
    // artifact vs the native Memo.
    let memo = DenseMemo::new(native.labels);
    let covered = vec![0i32; n * 64];
    let (sizes_xla, mg_xla) = xla.mg_compute(&memo.labels, &covered)?;
    anyhow::ensure!(sizes_xla == memo.sizes, "component-size tables differ");
    let pool = infuser::util::ThreadPool::new(4);
    let mg_native = memo.initial_gains(&pool);
    let max_diff = mg_native
        .iter()
        .zip(&mg_xla)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    anyhow::ensure!(max_diff < 1e-9, "marginal gains differ by {max_diff}");
    println!("\nstage B — memoized marginal gains: identical (max |d| = {max_diff:.1e})");

    // ---- Stage C: full INFUSER-MG seed selection with each engine.
    let params = InfuserParams {
        k: 16,
        common: infuser::api::RunOptions::new().r_count(64).seed(9).threads(4),
        ..Default::default()
    };
    let t = Timer::start();
    let res_native = InfuserMg::new(params).run_with_engine(&graph, &NativeEngine, &Budget::unlimited())?;
    let sel_native = t.secs();
    let t = Timer::start();
    let res_xla = InfuserMg::new(params).run_with_engine(&graph, &xla, &Budget::unlimited())?;
    let sel_xla = t.secs();
    anyhow::ensure!(res_native.seeds == res_xla.seeds, "seed sets differ across engines");
    anyhow::ensure!(
        (res_native.influence - res_xla.influence).abs() < 1e-9,
        "influence estimates differ"
    );
    println!("\nstage C — full INFUSER-MG (K=16):");
    println!("  native engine  {sel_native:>7.3}s");
    println!("  xla engine     {sel_xla:>7.3}s (warm executable)");
    println!("  seeds identical: {:?}", &res_native.seeds[..8.min(res_native.seeds.len())]);

    // ---- Stage D: serve a batch of requests through the XLA path and
    // report latency/throughput (the serving-style metric).
    let batch = 16usize;
    let t = Timer::start();
    let mut lat = Vec::with_capacity(batch);
    for req in 0..batch {
        let t1 = Timer::start();
        let o = PropagateOpts { seed: 1000 + req as u64, ..opts };
        let _ = xla.propagate(&graph, &o)?;
        lat.push(t1.secs());
    }
    let total = t.secs();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("\nstage D — {batch} propagation requests through PJRT:");
    println!(
        "  p50 {:.1} ms   p95 {:.1} ms   throughput {:.1} req/s ({:.1}M edge-sims/s)",
        lat[batch / 2] * 1e3,
        lat[batch * 95 / 100] * 1e3,
        batch as f64 / total,
        (batch as f64 * m2 as f64 * 64.0) / total / 1e6,
    );

    // ---- Independent quality check.
    let score = oracle::influence_score(
        &graph,
        &res_xla.seeds,
        &oracle::OracleParams { r_count: 1024, seed: 5, threads: 4 },
    );
    println!("\noracle sigma(S) = {score:.1} (internal estimate {:.1})", res_xla.influence);
    println!("\nE2E OK: all three layers compose; engines agree bit-for-bit.");
    Ok(())
}
