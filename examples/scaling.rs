//! Thread-scaling sweep (the shape of the paper's Fig. 6): INFUSER-MG
//! wall-clock at τ ∈ {1, 2, 4, 8, 16} on one graph, for p = 0.01 and
//! p = 0.1 (the paper's two constant-weight settings — the denser one
//! scales worse due to push-update contention, §4.6).
//!
//! ```bash
//! cargo run --release --example scaling [-- --dataset slashdot0811-s --k 10]
//! ```

use infuser::algo::infuser::{InfuserMg, InfuserParams};
use infuser::algo::Budget;
use infuser::config::DatasetRef;
use infuser::graph::WeightModel;
use infuser::util::args::Args;
use infuser::util::Timer;

fn main() -> infuser::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dataset = args.opt("dataset").unwrap_or("slashdot0811-s").to_string();
    let k = args.get_or("k", 10usize)?;
    let r = args.get_or("r", 128usize)?;
    let base = DatasetRef::parse(&dataset)?.load()?;
    println!("scaling on {dataset}: n={} m={} (K={k}, R={r})\n", base.num_vertices(), base.num_edges());

    let taus = [1usize, 2, 4, 8, 16];
    println!("{:>6} {:>12} {:>9} {:>12} {:>9}", "tau", "p=0.01 (s)", "speedup", "p=0.1 (s)", "speedup");
    let mut base_time = [0.0f64; 2];
    for &tau in &taus {
        let mut row = [0.0f64; 2];
        for (i, p) in [0.01f32, 0.1].iter().enumerate() {
            let g = base.clone().with_weights(WeightModel::Const(*p), 7);
            let params = InfuserParams {
                k,
                common: infuser::api::RunOptions::new().r_count(r).seed(3).threads(tau),
                ..Default::default()
            };
            let timer = Timer::start();
            let res = InfuserMg::new(params).run(&g, &Budget::unlimited())?;
            row[i] = timer.secs();
            std::hint::black_box(res);
        }
        if tau == 1 {
            base_time = row;
        }
        println!(
            "{:>6} {:>12.3} {:>8.2}x {:>12.3} {:>8.2}x",
            tau,
            row[0],
            base_time[0] / row[0],
            row[1],
            base_time[1] / row[1]
        );
    }
    println!("\n(paper Fig. 6: 3–5x at tau=16; denser p scales worse — push contention)");
    Ok(())
}
