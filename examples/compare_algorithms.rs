//! Algorithm shoot-out on one graph — a miniature of the paper's
//! Tables 4/5/7: MIXGREEDY vs FUSEDSAMPLING vs INFUSER-MG vs IMM(ε=0.5)
//! vs IMM(ε=0.13), common-oracle rescoring included.
//!
//! ```bash
//! cargo run --release --example compare_algorithms [-- --dataset nethep-s --k 10]
//! ```

use infuser::config::{AlgoSpec, DatasetRef, ExperimentConfig};
use infuser::coordinator::{render_grid, CellResult, Runner};
use infuser::graph::WeightModel;
use infuser::util::args::Args;

fn main() -> infuser::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dataset = args.opt("dataset").unwrap_or("nethep-s").to_string();
    let order = infuser::graph::OrderStrategy::parse(args.opt("order").unwrap_or("identity"))?;
    let cfg = ExperimentConfig {
        datasets: vec![DatasetRef::parse(&dataset)?],
        settings: vec![WeightModel::Const(0.05)],
        algos: vec![
            AlgoSpec::MixGreedy,
            AlgoSpec::FusedSampling,
            AlgoSpec::InfuserMg,
            AlgoSpec::InfuserSketch,
            AlgoSpec::Imm { epsilon: 0.5 },
            AlgoSpec::Imm { epsilon: 0.13 },
        ],
        k: args.get_or("k", 10usize)?,
        oracle_r: 1024,
        options: infuser::api::RunOptions::new()
            .r_count(args.get_or("r", 128usize)?)
            .threads(args.get_or(
                "threads",
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
            )?)
            .seed(args.get_or("seed", 0u64)?)
            .lanes(infuser::simd::LaneWidth::parse(args.opt("lanes").unwrap_or("8"))?)
            .order(order)
            .timeout(Some(std::time::Duration::from_secs(args.get_or(
                "timeout", 300u64,
            )?))),
        orders: vec![order],
    };
    println!(
        "comparing {} algorithms on {dataset} (K={}, R={}, tau={})\n",
        cfg.algos.len(),
        cfg.k,
        cfg.options.r_count,
        cfg.options.threads
    );
    let runner = Runner::new(cfg);
    let cells: Vec<CellResult> = runner.run_grid()?;

    println!("{}", render_grid(&cells, "Execution time (s)", |o| o.time_cell()).render());
    println!("{}", render_grid(&cells, "Tracked memory (GB)", |o| o.mem_cell()).render());
    println!(
        "{}",
        render_grid(&cells, "Influence (common mt19937 oracle, R=1024)", |o| o
            .influence_cell())
        .render()
    );

    // The paper's headline shape: INFUSER-MG fastest among the greedy
    // family while matching the oracle-rescored quality of IMM(ε=0.13).
    let secs = |algo: &str| {
        cells
            .iter()
            .find(|c| c.algo == algo)
            .and_then(|c| c.outcome.secs())
    };
    if let (Some(mix), Some(inf)) = (secs("MixGreedy"), secs("Infuser-MG")) {
        println!("speedup over MixGreedy: {:.1}x", mix / inf);
    }
    if let (Some(imm), Some(inf)) = (secs("IMM(e=0.13)"), secs("Infuser-MG")) {
        println!("speedup over IMM(e=0.13): {:.1}x", imm / inf);
    }
    Ok(())
}
