//! Quickstart: generate a scale-free network, run INFUSER-MG, verify the
//! seed set with the mt19937 oracle.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use infuser::algo::infuser::{InfuserMg, InfuserParams};
use infuser::algo::{oracle, Budget};
use infuser::gen::{self, GenSpec};
use infuser::graph::WeightModel;
use infuser::util::Timer;

fn main() -> infuser::Result<()> {
    // A 20k-vertex Barabási–Albert network with constant edge probability
    // p = 0.05 — the shape of the paper's co-purchase/collaboration nets.
    let graph = gen::generate(&GenSpec::barabasi_albert(20_000, 4, 42))
        .with_weights(WeightModel::Const(0.05), 7);
    println!(
        "graph: n={} m={} avg_deg={:.2}",
        graph.num_vertices(),
        graph.num_edges(),
        graph.avg_degree()
    );

    // INFUSER-MG: K=16 seeds from R=256 fused, batched simulations.
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let params = InfuserParams {
        k: 16,
        common: infuser::api::RunOptions::new().r_count(256).seed(1).threads(threads),
        ..Default::default()
    };
    let timer = Timer::start();
    let res = InfuserMg::new(params).run(&graph, &Budget::unlimited())?;
    let secs = timer.secs();

    println!("\nINFUSER-MG ({threads} threads): {secs:.3}s");
    println!("seeds: {:?}", res.seeds);
    println!("internal estimate sigma(S) = {:.1}", res.influence);
    for (name, value) in &res.counters {
        println!("  {name} = {value:.0}");
    }

    // Independent verification with the classical mt19937 oracle.
    let score = oracle::influence_score(
        &graph,
        &res.seeds,
        &oracle::OracleParams { r_count: 2048, seed: 0xFEED, threads },
    );
    println!("oracle sigma(S) over 2048 simulations = {score:.1}");
    let rel = (res.influence - score).abs() / score;
    println!("estimator agreement: {:.1}%", 100.0 * (1.0 - rel));
    anyhow::ensure!(rel < 0.05, "internal estimate drifted >5% from oracle");
    Ok(())
}
